"""Checkpointing: atomicity, GC, resume, reshard-on-load (elastic restart)."""
import dataclasses
import json
import os
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "layers": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,))},
        "step_scale": jnp.float32(0.5),
    }


class TestRoundtrip:
    def test_save_restore_exact(self, tmp_path):
        ckpt = Checkpointer(tmp_path, keep=2)
        t = _tree()
        ckpt.save(7, t)
        restored, step = ckpt.restore(jax.tree.map(jnp.zeros_like, t))
        assert step == 7
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save(self, tmp_path):
        ckpt = Checkpointer(tmp_path)
        t = _tree()
        ckpt.save_async(3, t)
        ckpt.wait()
        assert ckpt.latest_step() == 3

    def test_latest_picks_newest_complete(self, tmp_path):
        ckpt = Checkpointer(tmp_path, keep=10)
        ckpt.save(1, _tree())
        ckpt.save(5, _tree(1))
        # a torn write (tmp dir) must be invisible
        (tmp_path / "step_000000000009.tmp").mkdir()
        # an incomplete dir without manifest must be invisible
        (tmp_path / "step_000000000008").mkdir()
        assert ckpt.latest_step() == 5

    def test_gc_keeps_n(self, tmp_path):
        ckpt = Checkpointer(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            ckpt.save(s, _tree(s))
        assert ckpt.all_steps() == [3, 4]

    def test_bf16_bank_state_roundtrips_at_storage_dtype(self, tmp_path):
        """The PR-6 dtype policy's persistence leg: a bf16-stored fused
        BankState checkpoints and restores bit-exact WITHOUT being upcast —
        capacity planning relies on the on-disk and in-HBM footprints
        agreeing."""
        from repro.core.easi import EASIConfig
        from repro.core.smbgd import SMBGDConfig
        from repro.stream import SeparatorBank

        ecfg = EASIConfig(n_components=2, n_features=4, mu=1e-3)
        ocfg = SMBGDConfig(batch_size=8, mu=1e-3, beta=0.9, gamma=0.5)
        bank = SeparatorBank(
            ecfg, ocfg, n_streams=3, fused=True,
            dtype_policy="bf16", autotune=False,
        )
        key = jax.random.PRNGKey(0)
        st, _ = bank.step(
            bank.init(key), jax.random.normal(jax.random.fold_in(key, 1), (3, 8, 4))
        )
        assert st.B.dtype == jnp.bfloat16
        ckpt = Checkpointer(tmp_path)
        ckpt.save(1, st._asdict())
        restored, step = ckpt.restore(
            jax.tree.map(jnp.zeros_like, st._asdict())
        )
        assert step == 1
        assert restored["B"].dtype == jnp.bfloat16
        assert restored["H_hat"].dtype == jnp.bfloat16
        for name in ("B", "H_hat", "step", "conv"):
            np.testing.assert_array_equal(
                np.asarray(restored[name]), np.asarray(getattr(st, name))
            )
        # and the restored state steps in place of the original, bit-exact
        X = jax.random.normal(jax.random.fold_in(key, 2), (3, 8, 4))
        from repro.stream.bank import BankState

        a, Ya = bank.step(st, X)
        b, Yb = bank.step(BankState(**restored), X)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_shape_mismatch_rejected(self, tmp_path):
        ckpt = Checkpointer(tmp_path)
        ckpt.save(0, _tree())
        bad = {"layers": {"w": jnp.zeros((4, 4)), "b": jnp.zeros((16,))}, "step_scale": jnp.float32(0)}
        with pytest.raises(ValueError):
            ckpt.restore(bad)


class TestCorruptCheckpointErrors:
    """Damaged checkpoints must fail with actionable errors naming the leaf
    and the step — never a bare numpy traceback or, worse, silent garbage."""

    def test_truncated_npy_leaf(self, tmp_path):
        ckpt = Checkpointer(tmp_path)
        t = _tree()
        ckpt.save(4, t)
        leaf = tmp_path / "step_000000000004" / "layers__w.npy"
        leaf.write_bytes(leaf.read_bytes()[: 40])  # chop mid-header
        with pytest.raises(ValueError, match=r"layers__w.*corrupt|corrupt.*layers__w"):
            ckpt.restore(jax.tree.map(jnp.zeros_like, t))

    def test_garbage_npy_leaf(self, tmp_path):
        ckpt = Checkpointer(tmp_path)
        t = _tree()
        ckpt.save(4, t)
        (tmp_path / "step_000000000004" / "step_scale.npy").write_bytes(
            b"not an npy file at all"
        )
        with pytest.raises(ValueError, match="step_scale"):
            ckpt.restore(jax.tree.map(jnp.zeros_like, t))

    def test_missing_leaf_names_the_leaf(self, tmp_path):
        ckpt = Checkpointer(tmp_path)
        t = _tree()
        ckpt.save(2, t)
        os.remove(tmp_path / "step_000000000002" / "layers__b.npy")
        with pytest.raises(FileNotFoundError, match="layers__b"):
            ckpt.restore(jax.tree.map(jnp.zeros_like, t))

    def test_unknown_step_lists_available(self, tmp_path):
        ckpt = Checkpointer(tmp_path)
        ckpt.save(1, _tree())
        with pytest.raises(FileNotFoundError, match="available steps"):
            ckpt.restore(_tree(), step=99)

    def test_quarantine_fingerprint_mismatch_actionable(self, tmp_path):
        """A lifecycle snapshot whose quarantine membership disagrees with
        the checkpoint's stacked quar leaves must fail loudly."""
        from repro.core import EASIConfig, SMBGDConfig
        from repro.data.pipeline import MixedSignals
        from repro.data.resilience import FaultInjector
        from repro.data.sources import SyntheticSource
        from repro.serve import ConvergencePolicy, HealthPolicy, SeparationService
        from repro.stream import SeparatorBank

        ecfg = EASIConfig(n_components=2, n_features=4, mu=3e-3)
        ocfg = SMBGDConfig(batch_size=16, mu=3e-3, beta=0.9, gamma=0.5)

        def build():
            return SeparationService(
                SeparatorBank(
                    ecfg, ocfg, n_streams=2, fused=True, health_checks=True
                ),
                seed=0,
                policy=ConvergencePolicy(
                    threshold=1e-12, patience=10**6, min_ticks=10**6
                ),
                health_policy=HealthPolicy(
                    max_rollbacks=1, window=30, probe_every=4, probation=2
                ),
            )

        svc = build()
        svc.admit(
            "q",
            source=FaultInjector(
                SyntheticSource(MixedSignals(m=4, n=2, batch=16, seed=0)),
                {i: "nan" for i in range(8)},
            ),
        )
        for _ in range(12):
            svc.run_tick()
            if svc.status("q") == "quarantined":
                break
        assert svc.status("q") == "quarantined"
        life = svc.lifecycle
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=1)
        # tamper: rename the quarantined session in the snapshot
        life["quarantined"][0][0] = "not-q"
        dup = build()
        with pytest.raises(ValueError, match="quarantine|fingerprint"):
            dup.restore(ckpt, lifecycle=life)


class TestServiceLifecycleRoundtrip:
    """Queue + convergence-policy state across a checkpoint boundary: the
    arrays ride the Checkpointer, the host-side lifecycle snapshot rides
    alongside (JSON-able), and a restored service resumes the SAME lifecycle
    trajectory — monitors, queue order and all."""

    def _svc(self, **kw):
        from repro.core import EASIConfig, SMBGDConfig
        from repro.serve.engine import ConvergencePolicy, SeparationService
        from repro.stream import SeparatorBank

        ecfg = EASIConfig(n_components=2, n_features=4, mu=2e-3)
        ocfg = SMBGDConfig(batch_size=8, mu=2e-3, beta=0.9, gamma=0.5)
        return SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=2),
            seed=0,
            policy=ConvergencePolicy(threshold=10.0, patience=3, min_ticks=4),
            max_queue=4,
            **kw,
        )

    def test_queue_and_policy_state_roundtrip(self, tmp_path):
        svc = self._svc()
        for sid in ("a", "b", "q1", "q2"):
            svc.admit(sid)
        X = jax.random.normal(jax.random.PRNGKey(0), (8, 4))
        for k in range(2):  # part-way to convergence: monitors mid-flight
            svc.step({"a": X, "b": X})
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=3)
        snap = json.loads(json.dumps(svc.lifecycle))  # must survive JSON

        svc2 = self._svc()
        got = svc2.restore(ckpt, lifecycle=snap)
        assert got == 3
        assert svc2.sessions == svc.sessions
        assert svc2.queued == ("q1", "q2")
        assert svc2.session_stats("a")["conv_below"] == 2
        # the restored service reaches convergence on the same tick as the
        # original, evicting + backfilling identically
        for k in range(2):
            o1 = svc.step({"a": X, "b": X})
            o2 = svc2.step({"a": X, "b": X})
            for sid in o1:
                np.testing.assert_array_equal(np.asarray(o1[sid]), np.asarray(o2[sid]))
        for s in (svc, svc2):
            assert s.status("a") == "finished" and s.status("q1") == "active"
        np.testing.assert_allclose(
            np.asarray(svc.finished["a"].state.B),
            np.asarray(svc2.finished["a"].state.B),
            rtol=1e-6, atol=1e-7,
        )

    def test_restore_rejects_queue_session_overlap(self, tmp_path):
        svc = self._svc()
        svc.admit("a")
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=0)
        svc2 = self._svc()
        with pytest.raises(ValueError, match="overlap"):
            svc2.restore(
                ckpt, lifecycle={"sessions": {"a": 0}, "queue": ["a"]}
            )
        with pytest.raises(ValueError, match="overlap"):
            svc2.restore(
                ckpt, lifecycle={"sessions": {}, "queue": ["q", "q"]}
            )

    def test_bank_conv_statistic_roundtrips(self, tmp_path):
        """BankState.conv is a first-class leaf: exact across save/restore."""
        svc = self._svc()
        svc.admit("a")
        svc.step({"a": jax.random.normal(jax.random.PRNGKey(1), (8, 4))})
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=1)
        svc2 = self._svc()
        svc2.restore(ckpt, lifecycle=svc.lifecycle)
        np.testing.assert_array_equal(
            np.asarray(svc.state.conv), np.asarray(svc2.state.conv)
        )
        assert np.all(np.isfinite(np.asarray(svc2.state.conv)[:1]))


class TestDriftLifecycleRoundtrip:
    """Scheduler + drift-watchdog state across a checkpoint boundary, taken
    MID-DRIFT: hot monitors, boost countdowns, per-slot μ multipliers,
    scheduling metadata and source cursors all resume, and the restored
    service replays the original's exact trajectory."""

    def _svc(self):
        from repro.core import EASIConfig, SMBGDConfig
        from repro.serve import (
            ConvergencePolicy,
            DriftPolicy,
            PriorityScheduler,
            SeparationService,
        )
        from repro.stream import SeparatorBank

        ecfg = EASIConfig(n_components=2, n_features=4, mu=3e-3)
        ocfg = SMBGDConfig(batch_size=16, mu=3e-3, beta=0.9, gamma=0.5)
        return SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=1),
            seed=0,
            policy=ConvergencePolicy(
                threshold=0.025, patience=5, min_ticks=50, ema=0.9
            ),
            drift_policy=DriftPolicy(
                retrigger=0.03, patience=2, ema=0.8, cooldown=3,
                mode="boost", boost=4.0, boost_ticks=60,
            ),
            # tenant "suspended" has quota 0: its sessions ride the queue
            # (through the checkpoint) without ever contending for the slot
            scheduler=PriorityScheduler(max_queue=4, quotas={"suspended": 0}),
        )

    def _source(self):
        from repro.data.pipeline import MixedSignals
        from repro.data.sources import SyntheticSource

        pipe = MixedSignals(m=4, n=2, batch=16, seed=0, drift_rate=1.2 / 80)
        return SyntheticSource(pipe, drift_start=80, drift_stop=85)

    def test_mid_drift_roundtrip_resumes_exact_trajectory(self, tmp_path):
        svc = self._svc()
        src = svc_src = self._source()
        svc.admit("u", source=src, tenant="acme", priority=5.0)
        # rides the queue through the ckpt (quota-gated, so "u" stays hot)
        svc.admit("waiting", tenant="suspended", priority=1.0)
        # serve through convergence → hot → drift fires → μ boost engaged
        for _ in range(95):
            svc.run_tick()
        assert svc.drift_events and "u" in svc._boost_left  # mid-re-adaptation
        boost_left_at_save = dict(svc._boost_left)
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=7)
        snap = json.loads(json.dumps(svc.lifecycle))  # must survive JSON

        svc2 = self._svc()
        got = svc2.restore(ckpt, lifecycle=snap)
        assert got == 7
        # scheduler state: queue order AND metadata resumed
        assert svc2.queued == ("waiting",)
        assert svc2.scheduler.meta_of("waiting").priority == 1.0
        # watchdog state: boost countdown + μ row resumed exactly
        assert svc2._boost_left == boost_left_at_save
        np.testing.assert_array_equal(
            svc2._effective_mu_scale(), svc._effective_mu_scale()
        )
        np.testing.assert_array_equal(svc2._boost_scale, svc._boost_scale)
        # source re-binds and seeks to the recorded cursor
        src2 = self._source()
        svc2.bind_source("u", src2)
        assert src2.position == svc_src.position
        # both services now walk the identical trajectory (boost expiry and
        # re-convergence included)
        for _ in range(120):
            o1, o2 = svc.run_tick(), svc2.run_tick()
            for sid in o1:
                np.testing.assert_allclose(
                    np.asarray(o1[sid]), np.asarray(o2[sid]), rtol=1e-6, atol=1e-7
                )
        assert svc.status("u") == svc2.status("u") == "converged"
        assert svc2._boost_left == svc._boost_left == {}
        np.testing.assert_array_equal(
            svc2._effective_mu_scale(), svc._effective_mu_scale()
        )

    def test_hot_monitor_roundtrips(self, tmp_path):
        svc = self._svc()
        svc.admit("u", source=self._source())
        for _ in range(70):
            svc.run_tick()
        assert svc.status("u") == "converged"  # hot under drift watch
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=1)
        snap = json.loads(json.dumps(svc.lifecycle))
        assert snap["hot"]["u"]["seen"] > 0

        svc2 = self._svc()
        svc2.restore(ckpt, lifecycle=snap)
        assert svc2.status("u") == "converged"
        assert dataclasses.asdict(svc2._hot["u"]) == snap["hot"]["u"]

    def test_restore_rejects_bad_mu_scale(self, tmp_path):
        svc = self._svc()
        svc.admit("u")
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=0)
        svc2 = self._svc()
        with pytest.raises(ValueError, match="mu_scale"):
            svc2.restore(
                ckpt,
                lifecycle={"sessions": {"u": 0}, "mu_scale": [1.0, 1.0, 1.0]},
            )

    def test_restore_rejects_drift_state_without_drift_policy(self, tmp_path):
        """A snapshot carrying hot/boost/μ state must not restore into a
        service that cannot run it (it would crash or silently drift from
        the original trajectory)."""
        from repro.core import EASIConfig, SMBGDConfig
        from repro.serve import ConvergencePolicy, SeparationService
        from repro.stream import SeparatorBank

        svc = self._svc()
        svc.admit("u", source=self._source())
        for _ in range(95):  # through convergence → hot → boost engaged
            svc.run_tick()
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=2)
        snap = json.loads(json.dumps(svc.lifecycle))
        assert snap["boost"] or snap["hot"]  # the snapshot carries drift state

        ecfg = EASIConfig(n_components=2, n_features=4, mu=3e-3)
        ocfg = SMBGDConfig(batch_size=16, mu=3e-3, beta=0.9, gamma=0.5)
        plain = SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=1),
            seed=0,
            policy=ConvergencePolicy(threshold=0.025, patience=5, min_ticks=50),
        )
        with pytest.raises(ValueError, match="drift"):
            plain.restore(ckpt, lifecycle=snap)
        # dropping the watch state restores fine (arrays are still valid)
        snap2 = dict(
            snap, hot={}, boost={}, mu_scale=None, mu_boost_scale=None,
            mu_cut_scale=None, mu_ctrl_scale=None, mu_cut_on=None,
        )
        plain.restore(ckpt, lifecycle=snap2)
        assert plain.sessions == svc.sessions


class TestParkedProbeRoundtrip:
    """The batched probe engine's in-flight state across a checkpoint
    boundary: probe cadence counter, due-batch membership (park order),
    parked drift-monitor EMAs, scheduling metadata and the frozen separator
    arrays all round-trip exactly, and the restored watchdog walks the same
    probe trajectory."""

    PROBE_EVERY = 3

    def _svc(self, probe_batch=4):
        from repro.core import EASIConfig, SMBGDConfig
        from repro.serve import ConvergencePolicy, DriftPolicy, SeparationService
        from repro.stream import SeparatorBank

        ecfg = EASIConfig(n_components=2, n_features=4, mu=2e-3)
        ocfg = SMBGDConfig(batch_size=8, mu=2e-3, beta=0.9, gamma=0.5)
        return SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=2),
            seed=0,
            policy=ConvergencePolicy(threshold=0.025),
            drift_policy=DriftPolicy(
                mode="readmit", retrigger=1e-12, patience=4, ema=0.6,
                cooldown=2, probe_every=self.PROBE_EVERY,
                probe_batch=probe_batch,
            ),
            max_queue=4,
        )

    def _fill(self, svc, k=3):
        from repro.core import smbgd as smbgd_lib
        from repro.data.sources import ReplaySource
        from repro.serve import DriftMonitor, ParkedSession, SessionMeta
        from repro.serve.engine import EvictionRecord, SessionStats

        keys = jax.random.split(jax.random.PRNGKey(7), k)
        sources = {}
        for i in range(k):
            sid = f"p{i}"
            rng = np.random.default_rng(100 + i)
            X = rng.standard_normal((64 * 8, 4)).astype(np.float32)
            sources[sid] = X
            st = smbgd_lib.init_state(svc.bank.easi, keys[i])._replace(
                step=jnp.asarray(i + 1, jnp.int32)
            )
            svc._parked[sid] = ParkedSession(
                record=EvictionRecord(
                    state=st, stats=SessionStats(admitted_at=0.0),
                    monitor=None, reason="converged", tick=5 + i,
                ),
                source=ReplaySource(X, loop=True),
                monitor=DriftMonitor(),
                meta=SessionMeta(tenant="t", priority=float(i), order=i),
            )
        return sources

    def test_probe_state_roundtrips_exact(self, tmp_path):
        from repro.data.sources import ReplaySource

        svc = self._svc()
        sources = self._fill(svc)
        # run probes mid-cycle: cadence counter off-phase, monitor EMAs live
        for _ in range(self.PROBE_EVERY + 1):
            svc.run_tick()
        assert svc._probe_ticks == self.PROBE_EVERY + 1
        assert all(ps.monitor.seen == 1 for ps in svc.parked.values())
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=11)
        snap = json.loads(json.dumps(svc.lifecycle))  # must survive JSON

        svc2 = self._svc()
        got = svc2.restore(ckpt, lifecycle=snap)
        assert got == 11
        # cadence + due-batch membership (park order) resume exactly
        assert svc2._probe_ticks == svc._probe_ticks
        assert list(svc2.parked) == list(svc.parked)
        for sid, ps in svc.parked.items():
            ps2 = svc2.parked[sid]
            assert dataclasses.asdict(ps2.monitor) == dataclasses.asdict(ps.monitor)
            assert ps2.meta.asdict() == ps.meta.asdict()
            assert ps2.record.reason == ps.record.reason
            assert ps2.record.tick == ps.record.tick
            # frozen separator arrays are exact (stacked checkpoint leaves)
            np.testing.assert_array_equal(
                np.asarray(ps2.record.state.B), np.asarray(ps.record.state.B)
            )
            np.testing.assert_array_equal(
                np.asarray(ps2.record.state.H_hat),
                np.asarray(ps.record.state.H_hat),
            )
            assert int(ps2.record.state.step) == int(ps.record.state.step)
            assert ps2.source is None  # sources are live objects: re-bind
        # unbound parked sessions stay parked and skip probes (no crash)
        svc2.run_tick()
        assert set(svc2.parked) == set(svc.parked)
        # re-bind fresh sources: cursors re-seek to the recorded positions
        svc3 = self._svc()
        svc3.restore(ckpt, lifecycle=snap)
        for sid, X in sources.items():
            svc3.bind_source(sid, ReplaySource(X, loop=True))
            assert svc3.parked[sid].source.position == svc.parked[sid].source.position
        # both services now walk the identical probe trajectory — monitors,
        # events, eventual warm re-admissions and all
        for _ in range(7 * self.PROBE_EVERY):
            svc.run_tick()
            svc3.run_tick()
            assert {s: svc.status(s) for s in sources} == {
                s: svc3.status(s) for s in sources
            }
        assert [e.action for e in svc.drift_events] == [
            e.action for e in svc3.drift_events
        ]
        assert [e.session_id for e in svc.drift_events] == [
            e.session_id for e in svc3.drift_events
        ]
        assert svc.drift_events  # the trajectory actually re-admitted someone

    def test_restore_rejects_parked_without_readmit_policy(self, tmp_path):
        from repro.core import EASIConfig, SMBGDConfig
        from repro.serve import ConvergencePolicy, SeparationService
        from repro.stream import SeparatorBank

        svc = self._svc()
        self._fill(svc, k=2)
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=0)
        snap = json.loads(json.dumps(svc.lifecycle))
        assert snap["parked"]

        ecfg = EASIConfig(n_components=2, n_features=4, mu=2e-3)
        ocfg = SMBGDConfig(batch_size=8, mu=2e-3, beta=0.9, gamma=0.5)
        plain = SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=2),
            seed=0,
            policy=ConvergencePolicy(threshold=0.025),
        )
        with pytest.raises(ValueError, match="parked"):
            plain.restore(ckpt, lifecycle=snap)
        # overlap between parked and active sessions is rejected too
        svc2 = self._svc()
        bad = dict(snap, sessions={"p0": 0})
        with pytest.raises(ValueError, match="parked"):
            svc2.restore(ckpt, lifecycle=bad)
        # dropping the parked section restores fine
        svc2.restore(ckpt, lifecycle=dict(snap, parked=[]))
        assert svc2.parked == {}

    def test_restore_rejects_reordered_parked_snapshot(self, tmp_path):
        """The stacked parked_* leaves and the lifecycle snapshot are zipped
        by index: a snapshot whose park membership/order diverged from the
        checkpoint (same count) must be rejected, not silently attach frozen
        separators to the wrong sessions."""
        svc = self._svc()
        self._fill(svc, k=3)
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=0)
        snap = json.loads(json.dumps(svc.lifecycle))
        # same count, different order
        reordered = dict(snap, parked=list(reversed(snap["parked"])))
        svc2 = self._svc()
        with pytest.raises(ValueError, match="parked_\\* leaves"):
            svc2.restore(ckpt, lifecycle=reordered)
        # same count, different membership
        swapped = dict(
            snap,
            parked=[["ghost", snap["parked"][0][1]]] + snap["parked"][1:],
        )
        with pytest.raises(ValueError, match="parked_\\* leaves"):
            svc2.restore(ckpt, lifecycle=swapped)
        # the untouched snapshot still restores
        svc2.restore(ckpt, lifecycle=snap)
        assert list(svc2.parked) == list(svc.parked)


class TestElasticRestore:
    def test_reshard_on_load(self, tmp_path):
        """Checkpoints are topology-independent: restore with explicit
        shardings places leaves onto the (new) mesh — 1-device CPU here, the
        512→256 path exercised by the dry-run meshes."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        ckpt = Checkpointer(tmp_path)
        t = _tree()
        ckpt.save(2, t)
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
        restored, step = ckpt.restore(t, shardings=sh)
        assert step == 2
        for leaf in jax.tree.leaves(restored):
            assert leaf.sharding == NamedSharding(mesh, P())

    def test_restore_specific_step(self, tmp_path):
        ckpt = Checkpointer(tmp_path, keep=10)
        ckpt.save(1, _tree(1))
        ckpt.save(2, _tree(2))
        r1, s1 = ckpt.restore(_tree(), step=1)
        np.testing.assert_array_equal(
            np.asarray(r1["layers"]["w"]), np.asarray(_tree(1)["layers"]["w"])
        )


class TestWidthMismatchRestore:
    """Elastic-bank regression: a checkpoint saved at one bank width restores
    into a service whose bank has since grown or shrunk — sessions are
    RE-PLACED into the new free list (verbatim row carry, so trajectories
    stay bit-identical) instead of failing the per-leaf shape check; only
    when the live sessions genuinely exceed the new capacity does restore
    raise, and then it names the sids and both widths."""

    def _svc(self, S):
        from repro.core import EASIConfig, SMBGDConfig
        from repro.serve.engine import SeparationService
        from repro.stream import SeparatorBank

        ecfg = EASIConfig(n_components=2, n_features=4, mu=2e-3)
        ocfg = SMBGDConfig(batch_size=8, mu=2e-3, beta=0.9, gamma=0.5)
        return SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=S), seed=0, max_queue=4
        )

    def test_leaf_shapes_peeks_without_loading(self, tmp_path):
        ckpt = Checkpointer(tmp_path)
        ckpt.save(4, _tree())
        shapes = ckpt.leaf_shapes()
        assert shapes["layers__w"] == (8, 16)
        assert shapes["step_scale"] == ()
        with pytest.raises(FileNotFoundError):
            Checkpointer(tmp_path / "empty").leaf_shapes()

    def test_restore_into_wider_bank_replaces_and_resumes(self, tmp_path):
        svc = self._svc(2)
        svc.admit("a")
        svc.admit("b")
        X = jax.random.normal(jax.random.PRNGKey(0), (8, 4))
        svc.step({"a": X, "b": X})
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=1)
        snap = json.loads(json.dumps(svc.lifecycle))

        wide = self._svc(4)  # the bank grew since save
        assert wide.restore(ckpt, lifecycle=snap) == 1
        assert set(wide.sessions) == {"a", "b"}
        assert sorted(wide.sessions.values()) == [0, 1]  # re-placed low
        assert sorted(wide._free) == [2, 3]
        # the carried rows are verbatim: both services continue identically
        o1 = svc.step({"a": X, "b": X})
        o2 = wide.step({"a": X, "b": X})
        for sid in o1:
            np.testing.assert_array_equal(
                np.asarray(o1[sid]), np.asarray(o2[sid])
            )
        # and the freed width is genuinely usable
        assert wide.admit("c") is not None and wide.n_active == 3

    def test_restore_into_narrower_bank_replaces_high_slots(self, tmp_path):
        svc = self._svc(4)
        for sid in ("a", "b", "c", "d"):
            svc.admit(sid)
        X = jax.random.normal(jax.random.PRNGKey(1), (8, 4))
        svc.step({sid: X for sid in svc.sessions})
        # strand the survivors in the HIGH slots a narrow bank doesn't have
        svc.evict("a")
        svc.evict("b")
        assert max(svc.sessions.values()) >= 2
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=2)
        snap = json.loads(json.dumps(svc.lifecycle))

        narrow = self._svc(2)
        narrow.restore(ckpt, lifecycle=snap)
        assert sorted(narrow.sessions.values()) == [0, 1]
        for sid in ("c", "d"):
            got = narrow.bank.slot_state(narrow.state, narrow.sessions[sid])
            want = svc.bank.slot_state(svc.state, svc.sessions[sid])
            np.testing.assert_array_equal(
                np.asarray(got.B), np.asarray(want.B)
            )
            np.testing.assert_array_equal(
                np.asarray(got.H_hat), np.asarray(want.H_hat)
            )

    def test_restore_overflow_is_actionable(self, tmp_path):
        svc = self._svc(4)
        for sid in ("a", "b", "c"):
            svc.admit(sid)
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=0)
        snap = json.loads(json.dumps(svc.lifecycle))
        narrow = self._svc(2)
        with pytest.raises(ValueError) as ei:
            narrow.restore(ckpt, lifecycle=snap)
        msg = str(ei.value)
        # names both widths and the sids that don't fit
        assert "width 4" in msg and "width 2" in msg
        for sid in ("a", "b", "c"):
            assert sid in msg
        # the rejected restore left the narrow service untouched
        assert narrow.n_active == 0 and sorted(narrow._free) == [0, 1]

    def test_resize_history_roundtrips(self, tmp_path):
        svc = self._svc(2)
        svc.admit("a")
        svc.grow(4, reason="drill")
        svc.shrink(2, reason="drain")
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=5)
        snap = json.loads(json.dumps(svc.lifecycle))
        svc2 = self._svc(2)
        svc2.restore(ckpt, lifecycle=snap)
        hist = svc2.lifecycle["resize_history"]
        assert [h["action"] for h in hist] == ["grow", "shrink"]
        assert hist[0]["reason"] == "drill"
        # counters describe the restored epoch, not the old run
        assert svc2.metrics["n_grows"] == 0.0
