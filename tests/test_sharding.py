"""Sharding rules (pure-logic on stub meshes + 1-device integration) and the
dry-run cell bookkeeping."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES_BY_NAME
from repro.configs.registry import get_config
from repro.sharding import rules
from repro.sharding.compression import (
    compressed_psum,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)


def _stub_mesh(shape=(16, 16), axes=("data", "model")):
    m = types.SimpleNamespace()
    m.axis_names = axes
    m.devices = np.empty(shape, dtype=object)
    m.shape = dict(zip(axes, shape))
    return m


class TestParamSpecs:
    def test_attention_tp(self):
        axes = ("data", "model")
        cfg = get_config("minitron-8b")
        assert rules.param_spec("layers/b0/attn/wq", 2, cfg, axes) == P(("data",), "model")
        assert rules.param_spec("layers/b0/attn/wo", 2, cfg, axes) == P("model", ("data",))

    def test_fsdp_off_for_small(self):
        axes = ("data", "model")
        cfg = get_config("smollm-135m")  # fsdp=False
        assert rules.param_spec("layers/b0/attn/wq", 2, cfg, axes) == P(None, "model")

    def test_moe_expert_parallel(self):
        axes = ("data", "model")
        cfg = get_config("kimi-k2-1t-a32b")
        assert rules.param_spec("layers/moe/w_gate", 3, cfg, axes) == P("model", ("data",), None)
        assert rules.param_spec("layers/moe/w_down", 3, cfg, axes) == P("model", None, ("data",))
        assert rules.param_spec("layers/moe/router", 2, cfg, axes) == P(None, None)

    def test_embed_vocab_sharded(self):
        cfg = get_config("minitron-8b")
        assert rules.param_spec("embed", 2, cfg, ("data", "model")) == P("model", None)
        assert rules.param_spec("lm_head", 2, cfg, ("data", "model")) == P(None, "model")

    def test_norms_replicated(self):
        cfg = get_config("minitron-8b")
        assert rules.param_spec("layers/b0/attn_norm/scale", 1, cfg, ("data", "model")) == P(None)


class TestValidation:
    def test_indivisible_axis_dropped(self):
        mesh = _stub_mesh()
        spec = rules._validate_spec(P("model", None), (9, 4), mesh)  # 9 % 16 != 0
        assert spec == P(None, None)

    def test_divisible_kept(self):
        mesh = _stub_mesh()
        assert rules._validate_spec(P("model", None), (32, 4), mesh) == P("model", None)

    def test_tuple_axes_product(self):
        mesh = _stub_mesh((2, 16, 16), ("pod", "data", "model"))
        spec = rules._validate_spec(P(("pod", "data"), None), (64, 4), mesh)
        assert spec == P(("pod", "data"), None)
        spec = rules._validate_spec(P(("pod", "data"), None), (16, 4), mesh)  # 16 % 32
        assert spec == P(None, None)


class TestDataAndStateSpecs:
    def test_batch_sharded_over_dp(self):
        mesh = _stub_mesh()
        assert rules.data_spec((256, 4096), mesh) == P(("data",), None)

    def test_batch_one_replicated(self):
        mesh = _stub_mesh()
        assert rules.data_spec((1, 1), mesh) == P(None, None)

    def test_multipod_dp_axes(self):
        mesh = _stub_mesh((2, 16, 16), ("pod", "data", "model"))
        assert rules.data_spec((256, 4096), mesh) == P(("pod", "data"), None)

    def test_kv_cache_sequence_parallel(self):
        """Hkv=8 < model=16 → the 32k slot dim takes the model axis (SP)."""
        mesh = _stub_mesh()
        spec = rules.state_spec((32, 128, 8, 32768, 128), mesh, stacked=True)
        assert spec == P(None, ("data",), None, "model", None)

    def test_ssm_state_heads_sharded(self):
        mesh = _stub_mesh()
        spec = rules.state_spec((9, 1, 80, 64, 64), mesh, stacked=True)
        assert spec[2] == "model" or spec[3] == "model"


class TestCompression:
    def test_quantize_roundtrip_error(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1024,))
        q, s = quantize_int8(x)
        err = float(jnp.max(jnp.abs(dequantize_int8(q, s) - x)))
        assert err <= float(s) * 0.5 + 1e-7

    def test_compressed_psum_single_axis(self):
        """On a 1-member axis the compressed psum must reproduce the gradient
        up to quantization error, and EF must hold the residual."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh

        mesh = jax.make_mesh((1,), ("pod",))
        g = {"w": jax.random.normal(jax.random.PRNGKey(1), (64,))}
        ef = init_error_feedback(g)

        def f(gw, efw):
            out, new_ef = compressed_psum({"w": gw}, {"w": efw}, "pod")
            return out["w"], new_ef["w"]

        f_sh = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
        out, new_ef = f_sh(g["w"], ef["w"])
        scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
        assert float(jnp.max(jnp.abs(out - g["w"]))) <= scale * 0.5 + 1e-7
        np.testing.assert_allclose(
            np.asarray(out + new_ef), np.asarray(g["w"]), rtol=1e-5, atol=1e-6
        )


class TestDryrunBookkeeping:
    def test_skip_rules(self):
        from repro.launch.dryrun import cell_skip_reason

        long = SHAPES_BY_NAME["long_500k"]
        assert cell_skip_reason(get_config("minitron-8b"), long) is not None
        assert cell_skip_reason(get_config("gemma2-27b"), long) is not None
        assert cell_skip_reason(get_config("xlstm-1.3b"), long) is None
        assert cell_skip_reason(get_config("zamba2-2.7b"), long) is None
        assert cell_skip_reason(get_config("kimi-k2-1t-a32b"), SHAPES_BY_NAME["train_4k"]) is None

    def test_scan_groups(self):
        from repro.launch.dryrun import n_scan_groups

        assert n_scan_groups(get_config("minitron-8b")) == 32
        assert n_scan_groups(get_config("gemma2-27b")) == 23
        assert n_scan_groups(get_config("xlstm-1.3b")) == 6
        assert n_scan_groups(get_config("zamba2-2.7b")) == 9
        assert n_scan_groups(get_config("kimi-k2-1t-a32b")) == 60

    def test_collective_parser(self):
        from repro.launch.hlo_analysis import collective_bytes

        text = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
  %ag = bf16[512]{0} all-gather(bf16[256]{0} %y), dimensions={0}
  %nothing = f32[2]{0} add(f32[2]{0} %a, f32[2]{0} %b)
"""
        out = collective_bytes(text)
        assert out["all-reduce"] == 128 * 256 * 4
        assert out["all-gather"] == 512 * 2
        assert out["total"] == 128 * 256 * 4 + 1024

    def test_model_flops_positive(self):
        from repro.launch.flops import model_flops

        for arch in ("minitron-8b", "kimi-k2-1t-a32b", "xlstm-1.3b", "zamba2-2.7b"):
            cfg = get_config(arch)
            for s in ("train_4k", "prefill_32k", "decode_32k"):
                assert model_flops(cfg, SHAPES_BY_NAME[s]) > 0

    def test_moe_active_flops_much_smaller_than_total(self):
        from repro.launch.flops import _param_counts

        total, active = _param_counts(get_config("kimi-k2-1t-a32b"))
        assert total > 0.9e12  # ~1T total
        assert active < 0.05 * total  # 32B active
