"""Serving engine: deterministic greedy generation, family coverage, the
adaptive-ICA deployment loop (the paper's streaming use-case)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import AdaptiveICA, EASIConfig, SMBGDConfig, amari_index, global_system
from repro.data.pipeline import MixedSignals
from repro.models import model as M
from repro.serve.engine import Engine, ServeConfig


@pytest.mark.parametrize("arch", ["smollm-135m", "xlstm-1.3b", "musicgen-large"])
def test_greedy_generation_deterministic(arch):
    cfg = get_config(arch).reduced()  # reduced keeps family periodicity valid
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    scfg = ServeConfig(max_batch=2, max_len=48, temperature=0.0)
    if cfg.n_codebooks:
        prompts = jax.random.randint(key, (2, 8, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        prompts = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    out1, _ = Engine(cfg, params, scfg).prefill_and_generate(prompts, n_new=6)
    out2, _ = Engine(cfg, params, scfg).prefill_and_generate(prompts, n_new=6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape[:2] == (2, 6)
    assert int(out1.max()) < cfg.vocab_size


def test_generation_matches_forward_argmax():
    """Greedy next token after prefill == argmax of the parallel forward."""
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(), n_layers=2)
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    prompts = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    logits, _ = M.forward(params, {"tokens": prompts}, cfg)
    expected = jnp.argmax(logits[:, -1], axis=-1)
    out, _ = Engine(cfg, params, ServeConfig(max_batch=2, max_len=32)).prefill_and_generate(
        prompts, n_new=1
    )
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(expected))


class TestAdaptiveICADeployment:
    """The paper's deployment story: train+deploy in one system, tracking
    non-stationary mixing."""

    def test_streaming_partial_fit_tracks_drift(self):
        ecfg = EASIConfig(n_components=2, n_features=4, mu=3e-3)
        ocfg = SMBGDConfig(batch_size=16, mu=3e-3, beta=0.9, gamma=0.5)
        ica = AdaptiveICA(ecfg, ocfg)
        state = ica.init(jax.random.PRNGKey(0))
        pipe = MixedSignals(m=4, n=2, batch=16, seed=0, drift_rate=2e-6)
        fit = jax.jit(lambda s, x: ica.partial_fit(s, x))

        # converge on early mixing
        for step in range(1500):
            state, _ = fit(state, pipe.batch_for_step(step))
        pi_early = float(amari_index(global_system(state.B, pipe.mixing_at(1500))))
        # keep streaming while A(t) drifts; separator must keep tracking
        for step in range(1500, 3000):
            state, _ = fit(state, pipe.batch_for_step(step))
        pi_late = float(amari_index(global_system(state.B, pipe.mixing_at(3000))))
        assert pi_early < 0.2
        assert pi_late < 0.25, f"lost track under drift: {pi_late}"

    def test_transform_is_pure_deployment(self):
        ecfg = EASIConfig(n_components=2, n_features=4)
        ica = AdaptiveICA(ecfg, SMBGDConfig())
        state = ica.init(jax.random.PRNGKey(0))
        X = jax.random.normal(jax.random.PRNGKey(1), (100, 4))
        Y1 = ica.transform(state, X)
        Y2 = ica.transform(state, X)
        np.testing.assert_array_equal(np.asarray(Y1), np.asarray(Y2))
        assert Y1.shape == (100, 2)
