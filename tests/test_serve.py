"""Serving engine: deterministic greedy generation, family coverage, the
adaptive-ICA deployment loop (the paper's streaming use-case)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import AdaptiveICA, EASIConfig, SMBGDConfig, amari_index, global_system
from repro.core import smbgd as smbgd_lib
from repro.data.pipeline import MixedSignals
from repro.models import model as M
from repro.serve.engine import (
    ConvergencePolicy,
    Engine,
    SeparationService,
    ServeConfig,
)
from repro.stream import SeparatorBank
from _hypothesis_compat import given, settings, st


@pytest.mark.parametrize("arch", ["smollm-135m", "xlstm-1.3b", "musicgen-large"])
def test_greedy_generation_deterministic(arch):
    cfg = get_config(arch).reduced()  # reduced keeps family periodicity valid
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    scfg = ServeConfig(max_batch=2, max_len=48, temperature=0.0)
    if cfg.n_codebooks:
        prompts = jax.random.randint(key, (2, 8, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        prompts = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    out1, _ = Engine(cfg, params, scfg).prefill_and_generate(prompts, n_new=6)
    out2, _ = Engine(cfg, params, scfg).prefill_and_generate(prompts, n_new=6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape[:2] == (2, 6)
    assert int(out1.max()) < cfg.vocab_size


def test_generation_matches_forward_argmax():
    """Greedy next token after prefill == argmax of the parallel forward."""
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(), n_layers=2)
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    prompts = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    logits, _ = M.forward(params, {"tokens": prompts}, cfg)
    expected = jnp.argmax(logits[:, -1], axis=-1)
    out, _ = Engine(cfg, params, ServeConfig(max_batch=2, max_len=32)).prefill_and_generate(
        prompts, n_new=1
    )
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(expected))


class TestSampling:
    """Engine._sample: greedy vs temperature, with and without codebooks."""

    def _engine(self, temperature, n_codebooks=0):
        cfg = dataclasses.replace(
            get_config("musicgen-large" if n_codebooks else "smollm-135m").reduced(),
            n_layers=1,
        )
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        return Engine(
            cfg, params, ServeConfig(max_batch=2, max_len=16, temperature=temperature)
        ), cfg

    def test_greedy_is_argmax(self):
        eng, cfg = self._engine(temperature=0.0)
        logits = jax.random.normal(jax.random.PRNGKey(1), (2, 3, cfg.vocab_size))
        tok = eng._sample(logits)
        np.testing.assert_array_equal(
            np.asarray(tok), np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        )

    def test_temperature_samples_in_range_and_advances_key(self):
        eng, cfg = self._engine(temperature=1.0)
        logits = jax.random.normal(jax.random.PRNGKey(2), (2, 3, cfg.vocab_size))
        key_before = np.asarray(eng.key)
        tok = eng._sample(logits)
        assert tok.shape == (2,)
        assert int(tok.max()) < cfg.vocab_size and int(tok.min()) >= 0
        assert not np.array_equal(key_before, np.asarray(eng.key))
        # near-zero temperature concentrates on the argmax
        eng.scfg.temperature = 1e-4
        tok_cold = eng._sample(logits)
        np.testing.assert_array_equal(
            np.asarray(tok_cold), np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        )

    def test_codebook_path_samples_every_codebook(self):
        eng, cfg = self._engine(temperature=0.0, n_codebooks=4)
        K = cfg.n_codebooks
        logits = jax.random.normal(jax.random.PRNGKey(3), (2, 3, K, cfg.vocab_size))
        tok = eng._sample(logits)
        assert tok.shape == (2, K)
        np.testing.assert_array_equal(
            np.asarray(tok), np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        )


class TestSeparationService:
    """Continuous-batching admission into SeparatorBank slots."""

    def _svc(self, S=4, P=8):
        ecfg = EASIConfig(n_components=2, n_features=4, mu=2e-3)
        ocfg = SMBGDConfig(batch_size=P, mu=2e-3, beta=0.9, gamma=0.5)
        return SeparationService(SeparatorBank(ecfg, ocfg, n_streams=S), seed=0)

    def test_admit_step_evict_lifecycle(self):
        svc = self._svc()
        slot_a = svc.admit("a")
        svc.admit("b")
        assert svc.n_active == 2 and svc.n_free == 2
        X = jax.random.normal(jax.random.PRNGKey(1), (8, 4))
        out = svc.step({"a": X, "b": X})
        assert set(out) == {"a", "b"} and out["a"].shape == (8, 2)
        final = svc.evict("a")
        assert final.B.shape == (2, 4) and int(final.step) == 1
        # freed slot is reused by the next admission
        assert svc.admit("c") == slot_a

    def test_session_matches_independent_separator(self):
        """A session stepped through the service must follow exactly the
        trajectory of a standalone separator with the same init."""
        from repro.core import smbgd as smbgd_lib

        svc = self._svc()
        svc.admit("only")
        slot = svc._slot_of["only"]
        st_ref = svc.bank.slot_state(svc.state, slot)
        ecfg, ocfg = svc.bank.easi, svc.bank.opt
        for k in range(5):
            X = jax.random.normal(jax.random.PRNGKey(10 + k), (8, 4))
            out = svc.step({"only": X})
            st_ref, Y_ref = smbgd_lib.smbgd_batched_step(st_ref, X, ecfg, ocfg)
            np.testing.assert_allclose(
                np.asarray(out["only"]), np.asarray(Y_ref), rtol=1e-5, atol=1e-6
            )
        final = svc.evict("only")
        np.testing.assert_allclose(
            np.asarray(final.B), np.asarray(st_ref.B), rtol=1e-5, atol=1e-6
        )

    def test_idle_sessions_frozen(self):
        svc = self._svc()
        svc.admit("busy")
        svc.admit("idle")
        idle_before = svc.bank.slot_state(svc.state, svc._slot_of["idle"])
        for k in range(3):
            svc.step({"busy": jax.random.normal(jax.random.PRNGKey(k), (8, 4))})
        idle_after = svc.bank.slot_state(svc.state, svc._slot_of["idle"])
        np.testing.assert_array_equal(
            np.asarray(idle_before.B), np.asarray(idle_after.B)
        )
        assert int(idle_after.step) == 0

    def test_capacity_and_duplicate_guards(self):
        svc = self._svc(S=2)
        svc.admit("a")
        with pytest.raises(ValueError):
            svc.admit("a")
        svc.admit("b")
        with pytest.raises(RuntimeError):
            svc.admit("c")
        with pytest.raises(KeyError):
            svc.step({"ghost": jnp.zeros((8, 4))})

    def test_wrong_batch_shape_rejected(self):
        """A wrong-shaped mini-batch must error, not silently broadcast."""
        svc = self._svc()
        svc.admit("a")
        for bad in ((4,), (1, 4), (5, 4), (8, 3)):
            with pytest.raises(ValueError, match="batch shape"):
                svc.step({"a": jnp.zeros(bad)})

    def test_checkpoint_roundtrip_resumes_sessions(self, tmp_path):
        from repro.checkpoint.checkpointer import Checkpointer

        svc = self._svc()
        svc.admit("a")
        X = jax.random.normal(jax.random.PRNGKey(0), (8, 4))
        svc.step({"a": X})
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=1)
        sessions = svc.sessions

        svc2 = self._svc()
        got = svc2.restore(ckpt, sessions=sessions)
        assert got == 1
        for a, b in zip(svc.state, svc2.state):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # session "a" resumes in place: same trajectory as the original...
        np.testing.assert_array_equal(
            np.asarray(svc.step({"a": X})["a"]), np.asarray(svc2.step({"a": X})["a"])
        )
        # ...a new admission cannot steal its slot...
        slot_b = svc2.admit("b")
        assert slot_b != sessions["a"]
        # ...and the RNG key resumed too: both services mint the SAME next
        # session (resume equivalence), which differs from session "a"'s init
        slot_b_orig = svc.admit("b")
        np.testing.assert_array_equal(
            np.asarray(svc.bank.slot_state(svc.state, slot_b_orig).B),
            np.asarray(svc2.bank.slot_state(svc2.state, slot_b).B),
        )
        assert not np.array_equal(
            np.asarray(svc2.bank.slot_state(svc2.state, slot_b).B),
            np.asarray(svc2.bank.slot_state(svc2.state, sessions["a"]).B),
        )

    def test_restore_validates_session_map(self, tmp_path):
        from repro.checkpoint.checkpointer import Checkpointer

        svc = self._svc()
        svc.admit("live")
        svc.step({"live": jax.random.normal(jax.random.PRNGKey(0), (8, 4))})
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=0)
        state_before = jax.tree.map(np.asarray, svc.state._asdict())
        with pytest.raises(ValueError, match="out of range"):
            svc.restore(ckpt, sessions={"a": 99})
        with pytest.raises(ValueError, match="duplicate"):
            svc.restore(ckpt, sessions={"a": 0, "b": 0})
        # a rejected restore must leave the live service fully untouched
        assert svc.sessions == {"live": 0}
        for k, v in svc.state._asdict().items():
            np.testing.assert_array_equal(np.asarray(v), state_before[k])

    def test_empty_tick_is_noop(self):
        svc = self._svc()
        svc.admit("a")
        state_before = svc.state
        assert svc.step({}) == {}
        assert svc.state is state_before  # no fused launch dispatched

    def test_fused_service_matches_vmap_service(self):
        """The zero-copy fused tick (padded staging + megakernel + donated
        state) must serve the same outputs as the vmap bank service."""
        ecfg = EASIConfig(n_components=2, n_features=4, mu=2e-3)
        ocfg = SMBGDConfig(batch_size=8, mu=2e-3, beta=0.9, gamma=0.5)
        svc_r = SeparationService(SeparatorBank(ecfg, ocfg, n_streams=4), seed=0)
        svc_f = SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=4, fused=True), seed=0
        )
        for svc in (svc_r, svc_f):
            svc.admit("u1")
            svc.admit("u2")
        for k in range(5):
            X1 = jax.random.normal(jax.random.PRNGKey(10 + k), (8, 4))
            X2 = jax.random.normal(jax.random.PRNGKey(20 + k), (8, 4))
            o_r = svc_r.step({"u1": X1, "u2": X2})
            o_f = svc_f.step({"u1": X1, "u2": X2})
            assert o_f["u1"].shape == (8, 2)  # padded Y sliced at the boundary
            for sid in o_r:
                np.testing.assert_allclose(
                    np.asarray(o_r[sid]), np.asarray(o_f[sid]), rtol=1e-5, atol=1e-5
                )
        f_r, f_f = svc_r.evict("u1"), svc_f.evict("u1")
        assert f_f.B.shape == (2, 4)  # eviction hands back logical state
        np.testing.assert_allclose(
            np.asarray(f_r.B), np.asarray(f_f.B), rtol=1e-5, atol=1e-5
        )


def _mk_svc(S=2, P=8, fused=False, **kw):
    ecfg = EASIConfig(n_components=2, n_features=4, mu=2e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=2e-3, beta=0.9, gamma=0.5)
    return SeparationService(
        SeparatorBank(ecfg, ocfg, n_streams=S, fused=fused), seed=0, **kw
    )


def _batch(seed, P=8, m=4):
    return jax.random.normal(jax.random.PRNGKey(seed), (P, m))


class TestAdmissionQueue:
    """Bounded backpressure: admit() enqueues instead of raising."""

    def test_queue_fifo_order_under_backpressure(self):
        svc = _mk_svc(S=2, max_queue=3)
        assert svc.admit("a") is not None and svc.admit("b") is not None
        assert svc.admit("c") is None and svc.admit("d") is None
        assert svc.admit("e") is None
        assert svc.queued == ("c", "d", "e")
        assert svc.status("c") == "queued" and svc.metrics["n_queued"] == 3
        with pytest.raises(RuntimeError, match="bank full"):
            svc.admit("f")  # queue full too → backpressure raises
        with pytest.raises(ValueError, match="already admitted"):
            svc.admit("c")  # queued ids are already admitted
        # manual evictions drain the queue head-first into the freed slots
        slot_a = svc.sessions["a"]
        svc.evict("a")
        assert svc.status("c") == "active" and svc.sessions["c"] == slot_a
        svc.evict("b")
        assert svc.status("d") == "active"
        assert svc.queued == ("e",)

    def test_zero_queue_keeps_legacy_backpressure(self):
        svc = _mk_svc(S=1)  # max_queue defaults to 0
        svc.admit("a")
        with pytest.raises(RuntimeError, match="bank full"):
            svc.admit("b")

    def test_queued_session_activates_with_gamma_gate(self):
        """A backfilled session's separator is born at activation: step==0, so
        its first served tick gates γ (the paper's first-batch rule)."""
        svc = _mk_svc(S=1, max_queue=1)
        svc.admit("a")
        svc.admit("b")
        for k in range(3):
            svc.step({"a": _batch(k)})
        svc.evict("a")
        slot = svc.sessions["b"]
        assert int(svc.bank.slot_state(svc.state, slot).step) == 0

    def test_evict_queued_dequeues(self):
        svc = _mk_svc(S=1, max_queue=2)
        svc.admit("a")
        svc.admit("q1")
        svc.admit("q2")
        assert svc.evict("q1") is None  # cancellation: no device state
        assert svc.queued == ("q2",)
        assert svc.status("q1") == "unknown"
        # the free list was untouched: evicting the active session now
        # backfills q2 into the single slot
        svc.evict("a")
        assert svc.status("q2") == "active" and svc.n_free == 0

    def test_step_rejects_queued_and_unknown_ids(self):
        """The bugfix: a batch for a session with no slot must raise a
        KeyError that names the id's actual state (queued vs unknown) —
        never silently drop the data (mirrors the PR-3 ``evict`` fix)."""
        svc = _mk_svc(S=1, max_queue=2)
        svc.admit("active")
        svc.admit("waiting")
        with pytest.raises(KeyError, match="queued with no slot yet.*waiting"):
            svc.step({"active": _batch(0), "waiting": _batch(1)})
        with pytest.raises(KeyError, match="not active.*ghost"):
            svc.step({"ghost": _batch(2)})
        # the rejected tick touched nothing: the active session still serves
        assert svc.session_stats("active")["ticks"] == 0
        out = svc.step({"active": _batch(3)})
        assert set(out) == {"active"}

    def test_evict_unknown_raises_keyerror_and_corrupts_nothing(self):
        """The bugfix: an unknown id must raise KeyError without touching the
        free list (previously .pop(...) raised but a later variant could have
        appended a bogus slot)."""
        svc = _mk_svc(S=2, max_queue=1)
        svc.admit("a")
        free_before, sessions_before = svc.n_free, svc.sessions
        with pytest.raises(KeyError, match="neither active nor queued"):
            svc.evict("ghost")
        assert svc.n_free == free_before and svc.sessions == sessions_before
        # the service still serves and admits normally afterwards
        svc.admit("b")
        out = svc.step({"a": _batch(0), "b": _batch(1)})
        assert set(out) == {"a", "b"}


class TestSchedulers:
    """Pluggable admission policy: priority + per-tenant quotas, EDF."""

    def _svc(self, scheduler, S=2, **kw):
        from repro.serve import SeparationService
        from repro.stream import SeparatorBank

        ecfg = EASIConfig(n_components=2, n_features=4, mu=2e-3)
        ocfg = SMBGDConfig(batch_size=8, mu=2e-3, beta=0.9, gamma=0.5)
        return SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=S), seed=0,
            scheduler=scheduler, **kw,
        )

    def test_priority_orders_backfill(self):
        from repro.serve import PriorityScheduler

        svc = self._svc(PriorityScheduler(max_queue=4), S=1)
        svc.admit("running")
        svc.admit("low", priority=1.0)
        svc.admit("high", priority=9.0)
        svc.admit("mid", priority=5.0)
        assert svc.queued == ("high", "mid", "low")  # pop order, not FIFO
        svc.evict("running")
        assert svc.status("high") == "active"
        svc.evict("high")
        assert svc.status("mid") == "active"

    def test_priority_fifo_within_level(self):
        from repro.serve import PriorityScheduler

        svc = self._svc(PriorityScheduler(max_queue=4), S=1)
        svc.admit("running")
        svc.admit("first", priority=3.0)
        svc.admit("second", priority=3.0)
        svc.evict("running")
        assert svc.status("first") == "active"
        assert svc.queued == ("second",)

    def test_tenant_quota_gates_direct_admission_and_pop(self):
        from repro.serve import PriorityScheduler

        svc = self._svc(
            PriorityScheduler(max_queue=4, quotas={"acme": 1}), S=3
        )
        assert svc.admit("a1", tenant="acme") is not None
        # free slots exist, but acme is at quota → queued, not activated
        assert svc.admit("a2", tenant="acme") is None
        assert svc.status("a2") == "queued"
        # another tenant sails through
        assert svc.admit("b1", tenant="bravo") is not None
        # a2 activates only when acme's own slot frees
        svc.evict("b1")
        assert svc.status("a2") == "queued"  # b's slot freed: still gated
        svc.evict("a1")
        assert svc.status("a2") == "active"

    def test_deadline_scheduler_is_edf(self):
        from repro.serve import DeadlineScheduler

        svc = self._svc(DeadlineScheduler(max_queue=4), S=1)
        svc.admit("running")
        svc.admit("lax", deadline=90.0)
        svc.admit("urgent", deadline=10.0)
        svc.admit("whenever")  # no deadline: sorts last
        assert svc.queued == ("urgent", "lax", "whenever")
        svc.evict("running")
        assert svc.status("urgent") == "active"

    def test_scheduler_snapshot_roundtrip_preserves_meta(self):
        from repro.serve import PriorityScheduler, SessionMeta

        sched = PriorityScheduler(max_queue=4)
        sched.push("a", SessionMeta(tenant="t", priority=2.0, order=0))
        sched.push("b", SessionMeta(priority=7.0, order=1))
        snap = sched.snapshot()
        fresh = PriorityScheduler(max_queue=4)
        fresh.load(snap)
        assert fresh.ids() == ("b", "a")
        assert fresh.meta_of("a").tenant == "t"
        # PR-3 plain-sid lists still load (metadata defaults)
        legacy = PriorityScheduler(max_queue=4)
        legacy.load(["x", "y"])
        assert legacy.ids() == ("x", "y")

    def test_backpressure_still_raises_when_full(self):
        from repro.serve import PriorityScheduler

        svc = self._svc(PriorityScheduler(max_queue=1), S=1)
        svc.admit("a")
        svc.admit("b", priority=1.0)
        with pytest.raises(RuntimeError, match="bank full"):
            svc.admit("c", priority=99.0)  # priority buys order, not capacity


class TestSchedulerPropertyInvariants:
    """Satellite property sweep: random admit/evict/park/readmit traffic
    against the pluggable schedulers must never exceed tenant quotas, never
    drop or duplicate a session id, and always pop in EDF/priority order."""

    QUOTAS = {"t0": 1, "t1": 2}

    def _mk(self, kind):
        from repro.serve import (
            DeadlineScheduler,
            DriftPolicy,
            PriorityScheduler,
            SeparationService,
        )
        from repro.stream import SeparatorBank

        ecfg = EASIConfig(n_components=2, n_features=4, mu=2e-3)
        ocfg = SMBGDConfig(batch_size=8, mu=2e-3, beta=0.9, gamma=0.5)
        sched = (
            PriorityScheduler(max_queue=6, quotas=dict(self.QUOTAS))
            if kind == "priority"
            else DeadlineScheduler(max_queue=6)
        )
        return SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=3),
            seed=0,
            # trivially-satisfiable convergence → sessions park quickly, and
            # an always-above retrigger readmits them — maximum lifecycle
            # churn through the scheduler per tick
            policy=ConvergencePolicy(threshold=1e9, patience=1, min_ticks=2),
            drift_policy=DriftPolicy(
                mode="readmit", retrigger=1e-12, patience=1, cooldown=0,
                probe_every=1, probe_batch=4,
            ),
            scheduler=sched,
        )

    def _check_invariants(self, svc, kind, admitted, cancelled, meta_of):
        S = svc.bank.n_streams
        # slots conserved, never double-booked
        assert svc.n_active + svc.n_free == S
        slots = list(svc._slot_of.values())
        assert len(set(slots)) == len(slots)
        # no sid dropped or duplicated: every admitted sid is in exactly one
        # lifecycle bucket (cancelled queued sessions leave the system)
        buckets = {
            "active": set(svc.sessions),
            "queued": set(svc.queued),
            "parked": set(svc.parked),
            "finished": set(svc.finished),
        }
        seen = set()
        for ids in buckets.values():
            assert not ids & seen, f"sid in two buckets: {ids & seen}"
            seen |= ids
        for sid in admitted:
            if sid in cancelled:
                assert sid not in seen
            else:
                assert sid in seen, f"sid dropped: {sid}"
        # tenant quotas bound ACTIVE sessions at all times
        if kind == "priority":
            counts = {}
            for sid in svc.sessions:
                t = meta_of[sid][0]
                counts[t] = counts.get(t, 0) + 1
            for t, q in self.QUOTAS.items():
                assert counts.get(t, 0) <= q, f"tenant {t} over quota"
        # pop order: queued ids sorted by the policy's advertised key
        queued = svc.queued
        if kind == "priority":
            prios = [meta_of[sid][1] for sid in queued]
            assert prios == sorted(prios, reverse=True)
        else:
            deadlines = [meta_of[sid][2] for sid in queued]
            dated = [d for d in deadlines if d is not None]
            # every dated session pops before every dateless one, EDF inside
            assert deadlines[: len(dated)] == sorted(dated)
            assert all(d is None for d in deadlines[len(dated):])

    @pytest.mark.property
    @given(
        seed=st.integers(0, 10_000),
        kind=st.sampled_from(["priority", "deadline"]),
    )
    @settings(max_examples=10, deadline=None)
    def test_random_lifecycle_preserves_invariants(self, seed, kind):
        from repro.data.sources import ReplaySource

        rng = np.random.default_rng(seed)
        svc = self._mk(kind)
        data = rng.standard_normal((64 * 8, 4)).astype(np.float32)
        admitted, cancelled = [], set()
        meta_of = {}
        next_id = 0
        for _ in range(40):
            op = ("admit", "evict", "tick", "tick")[rng.integers(4)]
            if op == "admit":
                sid = f"s{next_id}"
                next_id += 1
                tenant = (None, "t0", "t1")[rng.integers(3)]
                priority = float(rng.integers(10))
                deadline = (
                    None if rng.integers(2) else float(rng.integers(100))
                )
                try:
                    svc.admit(
                        sid,
                        source=ReplaySource(data, loop=True),
                        tenant=tenant,
                        priority=priority,
                        deadline=deadline,
                    )
                except RuntimeError:
                    pass  # backpressure: sid never entered the system
                else:
                    admitted.append(sid)
                    meta_of[sid] = (tenant, priority, deadline)
            elif op == "evict" and admitted:
                sid = admitted[rng.integers(len(admitted))]
                status = svc.status(sid)
                try:
                    out = svc.evict(sid)
                except KeyError:
                    assert status in ("finished", "unknown")
                else:
                    if status == "queued":
                        assert out is None
                        cancelled.add(sid)
            else:
                svc.run_tick()
            self._check_invariants(svc, kind, admitted, cancelled, meta_of)


class TestConvergenceLifecycle:
    """Auto-eviction on convergence + same-tick backfill."""

    # random normal data keeps the separator jittering around a small but
    # finite update magnitude, so a generous threshold makes "convergence"
    # deterministic after min_ticks/patience — the machinery under test is
    # the lifecycle, not the ICA (tests/test_convergence.py covers that)
    POLICY = ConvergencePolicy(threshold=10.0, patience=2, min_ticks=3)

    @pytest.mark.parametrize("fused", [False, True])
    def test_auto_evict_and_same_tick_backfill(self, fused):
        events = []
        svc = _mk_svc(
            S=2, fused=fused, policy=self.POLICY, max_queue=2,
            on_admit=lambda sid, slot: events.append(("admit", sid, slot)),
            on_evict=lambda sid, rec: events.append(("evict", sid, rec.reason)),
        )
        for sid in ("a", "b", "c", "d"):
            svc.admit(sid)
        assert svc.queued == ("c", "d")
        ticks_to_evict = None
        for k in range(6):
            served = [s for s in ("a", "b") if svc.status(s) == "active"]
            if not served:
                break
            svc.step({sid: _batch(10 * k + i) for i, sid in enumerate(served)})
            if svc.status("a") == "finished" and ticks_to_evict is None:
                ticks_to_evict = k + 1
        # converged exactly when min_ticks AND patience were first satisfied
        assert ticks_to_evict == max(self.POLICY.min_ticks, self.POLICY.patience)
        rec = svc.finished["a"]
        assert rec.reason == "converged"
        assert rec.stats.ticks == ticks_to_evict
        assert rec.monitor.below >= self.POLICY.patience
        # same-tick backfill: at the eviction tick the queue head was already
        # active (events interleave evict→admit within one step() call)
        i_evict = events.index(("evict", "a", "converged"))
        backfills = [e for e in events[i_evict:] if e[0] == "admit"]
        assert backfills and backfills[0][1] == "c"
        assert svc.status("c") == "active"
        assert svc.metrics["n_auto_evicted"] >= 1

    def test_evicted_state_fidelity(self):
        """The auto-evicted SMBGDState must equal slot_state at eviction: a
        session stepped through churn follows exactly the trajectory of a
        standalone separator with the same init."""
        svc = _mk_svc(S=2, policy=self.POLICY, max_queue=2)
        svc.admit("only")
        slot = svc.sessions["only"]
        st_ref = svc.bank.slot_state(svc.state, slot)
        ecfg, ocfg = svc.bank.easi, svc.bank.opt
        k = 0
        while svc.status("only") == "active":
            X = _batch(100 + k)
            svc.step({"only": X})
            st_ref, _ = smbgd_lib.smbgd_batched_step(st_ref, X, ecfg, ocfg)
            k += 1
            assert k < 20, "policy never fired"
        final = svc.finished["only"].state
        np.testing.assert_allclose(
            np.asarray(final.B), np.asarray(st_ref.B), rtol=1e-5, atol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(final.H_hat), np.asarray(st_ref.H_hat), rtol=1e-5, atol=1e-6
        )
        assert int(final.step) == int(st_ref.step)

    def test_min_ticks_and_patience_gate_eviction(self):
        svc = _mk_svc(
            S=1, policy=ConvergencePolicy(threshold=10.0, patience=3, min_ticks=5)
        )
        svc.admit("a")
        for k in range(4):
            svc.step({"a": _batch(k)})
            assert svc.status("a") == "active"  # min_ticks floor holds
        svc.step({"a": _batch(99)})
        assert svc.status("a") == "finished"

    def test_idle_ticks_do_not_advance_convergence(self):
        """Only data ticks count: an idle session's monitor must not move."""
        svc = _mk_svc(S=2, policy=self.POLICY)
        svc.admit("busy")
        svc.admit("idle")
        k = 0
        while svc.status("busy") == "active":
            svc.step({"busy": _batch(k)})
            k += 1
            assert k < 10, "policy never fired"
        assert svc.status("busy") == "finished"
        assert svc.status("idle") == "active"
        assert svc.session_stats("idle")["conv_below"] == 0

    def test_amari_gate_vetoes_blind_eviction(self):
        """With a registered mixing matrix and an unreachable Amari bar, the
        blind statistic alone must NOT evict."""
        svc = _mk_svc(
            S=1,
            policy=ConvergencePolicy(
                threshold=10.0, patience=2, min_ticks=2, amari_threshold=1e-9
            ),
        )
        svc.admit("a")
        svc.set_mixing("a", jnp.eye(4)[:, :2])
        for k in range(6):
            svc.step({"a": _batch(k)})
        assert svc.status("a") == "active"  # vetoed every tick
        # unknown mixing → the blind statistic decides (same policy)
        svc2 = _mk_svc(
            S=1,
            policy=ConvergencePolicy(
                threshold=10.0, patience=2, min_ticks=2, amari_threshold=1e-9
            ),
        )
        svc2.admit("a")
        k = 0
        while svc2.status("a") == "active":
            svc2.step({"a": _batch(k)})
            k += 1
            assert k < 10, "policy never fired"
        assert svc2.status("a") == "finished"

    def test_seeded_churn_scenario(self):
        """Admissions interleaved with convergence-driven evictions: every
        session is served, evicted exactly once, keeps its stats, and the
        bank never over- or under-fills."""
        svc = _mk_svc(S=2, fused=True, policy=self.POLICY, max_queue=8)
        all_sids = [f"s{i}" for i in range(8)]
        pending = list(all_sids)
        for sid in pending[:4]:
            svc.admit(sid)
        pending = pending[4:]
        rng = np.random.default_rng(0)
        for tick in range(40):
            if pending and rng.random() < 0.5:  # interleaved arrivals
                svc.admit(pending.pop(0))
            served = [s for s in all_sids if svc.status(s) == "active"]
            if not served and not pending and not svc.queued:
                break
            if served:
                svc.step(
                    {s: _batch(1000 + 31 * tick + i) for i, s in enumerate(served)}
                )
            assert svc.n_active + svc.n_free == 2  # slots conserved
        finished = svc.pop_finished()
        assert sorted(finished) == sorted(all_sids)
        for sid, rec in finished.items():
            assert rec.reason == "converged"
            # per-session stats preserved through eviction
            assert rec.stats.ticks >= self.POLICY.min_ticks
            assert rec.stats.samples == rec.stats.ticks * 8
            assert rec.monitor.below >= self.POLICY.patience
        assert svc.metrics["n_auto_evicted"] == len(all_sids)
        assert svc.pop_finished() == {}  # drained

    def test_monitor_ema_matches_metrics_ema_update(self):
        """ConvergenceMonitor's host-side EMA must track core.metrics'
        jit-safe ema_update exactly (the two implementations are twins and
        must not drift)."""
        from repro.core import ema_update
        from repro.serve.engine import ConvergenceMonitor

        pol = ConvergencePolicy(threshold=0.1, patience=2, min_ticks=1, ema=0.7)
        mon = ConvergenceMonitor()
        smoothed = jnp.asarray(float("inf"))
        for x in (0.8, 0.4, 0.2, 0.05, 0.03):
            mon.update(x, pol)
            smoothed = ema_update(smoothed, x, pol.ema)
            np.testing.assert_allclose(mon.stat, float(smoothed), rtol=1e-6)
        # ema=0 passes raw values through in both
        mon0 = ConvergenceMonitor()
        pol0 = ConvergencePolicy(threshold=0.1, ema=0.0)
        mon0.update(0.25, pol0)
        assert mon0.stat == 0.25 == float(ema_update(jnp.inf, 0.25, 0.0))

    def test_lifecycle_snapshot_roundtrip_in_memory(self):
        svc = _mk_svc(S=2, policy=self.POLICY, max_queue=3)
        for sid in ("a", "b", "c"):
            svc.admit(sid)
        svc.step({"a": _batch(0), "b": _batch(1)})
        snap = svc.lifecycle
        assert snap["sessions"] == {"a": 0, "b": 1}
        # queue entries carry scheduling metadata now ([sid, meta] pairs);
        # restore() still also accepts the PR-3 plain-sid list format
        assert [sid for sid, _meta in snap["queue"]] == ["c"]
        assert snap["monitors"]["a"]["ticks"] == 1


class TestServiceMetrics:
    """Per-tick latency and per-session samples/sec counters (the ROADMAP
    metrics stub): counted on every flavour of bank."""

    def _svc(self, fused=False, **kw):
        ecfg = EASIConfig(n_components=2, n_features=4, mu=2e-3)
        ocfg = SMBGDConfig(batch_size=8, mu=2e-3, beta=0.9, gamma=0.5)
        return SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=4, fused=fused), seed=0, **kw
        )

    @pytest.mark.parametrize("fused", [False, True])
    def test_tick_and_sample_counters(self, fused):
        svc = self._svc(fused=fused, block_ticks=True)
        svc.admit("a")
        svc.admit("b")
        m0 = svc.metrics
        assert m0["n_ticks"] == 0 and np.isnan(m0["last_tick_s"])
        X = jax.random.normal(jax.random.PRNGKey(0), (8, 4))
        svc.step({"a": X, "b": X})
        svc.step({"a": X})
        m = svc.metrics
        assert m["n_ticks"] == 2
        assert m["total_samples"] == 8 * 3  # two sessions + one session
        assert m["last_tick_s"] > 0 and m["mean_tick_s"] > 0
        assert m["samples_per_s"] > 0
        assert m["n_active"] == 2 and m["n_free"] == 2

    def test_per_session_stats(self):
        svc = self._svc(block_ticks=True)
        svc.admit("busy")
        svc.admit("idle")
        X = jax.random.normal(jax.random.PRNGKey(1), (8, 4))
        for _ in range(3):
            svc.step({"busy": X})
        busy, idle = svc.session_stats("busy"), svc.session_stats("idle")
        assert busy["ticks"] == 3 and busy["samples"] == 24
        assert busy["samples_per_s"] > 0
        assert idle["ticks"] == 0 and idle["samples"] == 0
        svc.evict("busy")
        with pytest.raises(KeyError):
            svc.session_stats("busy")

    def test_restore_restarts_counters(self, tmp_path):
        from repro.checkpoint.checkpointer import Checkpointer

        svc = self._svc()
        svc.admit("a")
        X = jax.random.normal(jax.random.PRNGKey(2), (8, 4))
        svc.step({"a": X})
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=1)
        svc2 = self._svc()
        svc2.admit("a")
        svc2.step({"a": X})
        assert svc2.metrics["n_ticks"] == 1  # pre-restore traffic...
        svc2.restore(ckpt, sessions=svc.sessions)
        stats = svc2.session_stats("a")  # re-attached session is countable
        assert stats["ticks"] == 0
        # ...and BOTH observability surfaces restart at the restored epoch
        m = svc2.metrics
        assert m["n_ticks"] == 0 and m["total_samples"] == 0
        assert np.isnan(m["last_tick_s"])
        svc2.step({"a": X})
        assert svc2.session_stats("a")["ticks"] == 1
        assert svc2.metrics["n_ticks"] == 1


class TestAdaptiveICADeployment:
    """The paper's deployment story: train+deploy in one system, tracking
    non-stationary mixing."""

    @pytest.mark.slow
    def test_streaming_partial_fit_tracks_drift(self):
        ecfg = EASIConfig(n_components=2, n_features=4, mu=3e-3)
        ocfg = SMBGDConfig(batch_size=16, mu=3e-3, beta=0.9, gamma=0.5)
        ica = AdaptiveICA(ecfg, ocfg)
        state = ica.init(jax.random.PRNGKey(0))
        pipe = MixedSignals(m=4, n=2, batch=16, seed=0, drift_rate=2e-6)
        fit = jax.jit(lambda s, x: ica.partial_fit(s, x))

        # converge on early mixing
        for step in range(1500):
            state, _ = fit(state, pipe.batch_for_step(step))
        pi_early = float(amari_index(global_system(state.B, pipe.mixing_at(1500))))
        # keep streaming while A(t) drifts; separator must keep tracking
        for step in range(1500, 3000):
            state, _ = fit(state, pipe.batch_for_step(step))
        pi_late = float(amari_index(global_system(state.B, pipe.mixing_at(3000))))
        assert pi_early < 0.2
        assert pi_late < 0.25, f"lost track under drift: {pi_late}"

    def test_transform_is_pure_deployment(self):
        ecfg = EASIConfig(n_components=2, n_features=4)
        ica = AdaptiveICA(ecfg, SMBGDConfig())
        state = ica.init(jax.random.PRNGKey(0))
        X = jax.random.normal(jax.random.PRNGKey(1), (100, 4))
        Y1 = ica.transform(state, X)
        Y2 = ica.transform(state, X)
        np.testing.assert_array_equal(np.asarray(Y1), np.asarray(Y2))
        assert Y1.shape == (100, 2)
