"""In-kernel [Σy², Σy⁴] telemetry and the moment-scaled adaptive μ controller.

Three layers under test:

  * the kernel fold — ``ops.smbgd_step_bank(moments=True)`` /
    ``smbgd_probe_bank(moments=True)`` against the naive ``moments_ref``
    whole-array oracle and the vmap bank path, across ragged shapes, every
    nonlinearity, both storage dtypes and both DMA schedules — plus the
    bit-identity contract: ``moments`` is purely observational, every other
    output is unchanged by it,
  * the host-side ``MomentController`` — EMA kurtosis → μ multiplier with
    warmup, deadband, clamps, anneal and checkpoint round-trips,
  * the service composition — the three μ ladders (DriftPolicy boost,
    HealthPolicy cut, moment controller) write disjoint state and compose by
    the pinned rule: cut WINS while live, boost × controller MULTIPLY
    (the PR-9 composition bugfix regressions live here).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.easi import EASIConfig
from repro.core.nonlinearities import NONLINEARITIES
from repro.core.smbgd import SMBGDConfig
from repro.data.sources import ReplaySource
from repro.kernels.easi_gradient import ops as easi_ops
from repro.kernels.easi_gradient.ref import moments_ref, smbgd_step_bank_ref
from repro.serve import (
    ConvergencePolicy,
    MomentController,
    MomentPolicy,
    SeparationService,
)
from repro.stream import SeparatorBank


def _cfgs(P=8, n=2, m=4, mu=2e-3):
    return (
        EASIConfig(n_components=n, n_features=m, mu=mu),
        SMBGDConfig(batch_size=P, mu=mu, beta=0.9, gamma=0.5),
    )


def _padded_inputs(S, P, n, m, key, state_dtype=jnp.float32):
    """Persistent-layout operand set with real content in the logical block
    (same recipe as the fused-step sweep) and a mixed active mask."""
    lay = easi_ops.bank_layout(n, m, P)
    X = jnp.zeros((S, lay.P_pad, lay.m_pad)).at[:, :P, :m].set(
        jax.random.normal(key, (S, P, m))
    )
    B = jnp.zeros((S, lay.n_pad, lay.m_pad)).at[:, :n, :m].set(
        jax.random.normal(jax.random.fold_in(key, 1), (S, n, m)) * 0.3
    ).astype(state_dtype)
    H = jnp.zeros((S, lay.n_pad, lay.n_pad)).at[:, :n, :n].set(
        jax.random.normal(jax.random.fold_in(key, 2), (S, n, n)) * 0.1
    ).astype(state_dtype)
    W = jnp.zeros((S, lay.P_pad)).at[:, :P].set(
        jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (S, P))) * 0.01
    )
    step = jnp.arange(S, dtype=jnp.int32)
    gamma_hat = 0.1 + 0.8 * jax.random.uniform(jax.random.fold_in(key, 4), (S,))
    active = (jnp.arange(S) % 3 != 2).astype(jnp.int32)  # freeze every 3rd
    conv0 = jnp.arange(1.0, S + 1.0)
    return lay, (X, W, B, H, step, gamma_hat, active, conv0)


# ---------------------------------------------------------------------------
# kernel fold vs the naive oracle
# ---------------------------------------------------------------------------
class TestKernelMoments:
    def test_step_matches_ref_and_direct_oracle(self):
        S, P, n, m = 4, 16, 3, 5
        lay, args = _padded_inputs(S, P, n, m, jax.random.PRNGKey(0))
        Y, *_rest, mom = easi_ops.smbgd_step_bank(
            *args, block_p=lay.block_p, moments=True
        )
        *_refs, mom_ref = smbgd_step_bank_ref(*args, moments=True)
        np.testing.assert_allclose(
            np.asarray(mom), np.asarray(mom_ref), rtol=1e-5, atol=1e-6
        )
        # and against the whole-array reduction over the kernel's OWN Y —
        # padding contributes exact zeros, so padded ≡ logical sums
        active = np.asarray(args[6])
        for s in range(S):
            want = (
                np.asarray(moments_ref(Y[s]))
                if active[s]
                else np.zeros((2,), np.float32)
            )
            np.testing.assert_allclose(
                np.asarray(mom[s]), want, rtol=1e-4, atol=1e-6
            )
            np.testing.assert_allclose(
                np.asarray(mom[s]),
                np.asarray(moments_ref(Y[s, :P, :n])) if active[s] else 0.0,
                rtol=1e-4,
                atol=1e-6,
            )

    def test_frozen_streams_report_zero(self):
        lay, args = _padded_inputs(6, 8, 2, 4, jax.random.PRNGKey(3))
        _, _, mom = easi_ops.smbgd_probe_bank(
            *args, block_p=lay.block_p, moments=True
        )
        active = np.asarray(args[6])
        np.testing.assert_array_equal(
            np.asarray(mom)[active == 0], np.zeros((2, 2), np.float32)
        )
        assert np.all(np.asarray(mom)[active == 1] > 0)

    def test_probe_moments_equal_step_moments(self):
        """The freeze-only probe folds the same Y as the committing step."""
        lay, args = _padded_inputs(3, 8, 2, 4, jax.random.PRNGKey(5))
        *_outs, mom_step = easi_ops.smbgd_step_bank(
            *args, block_p=lay.block_p, moments=True
        )
        _, _, mom_probe = easi_ops.smbgd_probe_bank(
            *args, block_p=lay.block_p, moments=True
        )
        np.testing.assert_allclose(
            np.asarray(mom_step), np.asarray(mom_probe), rtol=1e-6, atol=0
        )

    @pytest.mark.property
    @given(
        S=st.integers(1, 4),
        shape=st.sampled_from([(2, 4), (3, 5), (2, 6), (4, 4)]),
        P=st.sampled_from([8, 16]),
        nonlinearity=st.sampled_from(sorted(NONLINEARITIES)),
        dtype=st.sampled_from(["f32", "bf16"]),
        prefetch=st.sampled_from([False, True]),
    )
    @settings(max_examples=20, deadline=None)
    def test_property_sweep(self, S, shape, P, nonlinearity, dtype, prefetch):
        """Fused fold ≡ naive oracle across ragged shapes, nonlinearities,
        storage dtypes and DMA schedules."""
        n, m = shape
        state_dtype = jnp.float32 if dtype == "f32" else jnp.bfloat16
        lay, args = _padded_inputs(
            S, P, n, m, jax.random.PRNGKey(S * 100 + P + n), state_dtype
        )
        *_outs, mom = easi_ops.smbgd_step_bank(
            *args,
            nonlinearity=nonlinearity,
            block_p=lay.block_p,
            prefetch=prefetch,
            moments=True,
        )
        *_refs, mom_ref = smbgd_step_bank_ref(
            *args, nonlinearity=nonlinearity, moments=True
        )
        np.testing.assert_allclose(
            np.asarray(mom), np.asarray(mom_ref), rtol=2e-4, atol=1e-6
        )


class TestMomentsBitIdentity:
    """``moments`` is purely observational: flipping it must not perturb a
    single bit of any other output, and the off paths must be exactly the
    pre-telemetry kernels."""

    def test_step_outputs_identical_on_off(self):
        lay, args = _padded_inputs(4, 16, 2, 4, jax.random.PRNGKey(7))
        off = easi_ops.smbgd_step_bank(*args, block_p=lay.block_p, moments=False)
        on = easi_ops.smbgd_step_bank(*args, block_p=lay.block_p, moments=True)
        for a, b in zip(off[:-1], on[:-1]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(
            np.asarray(off[-1]), np.zeros((4, 2), np.float32)
        )

    def test_probe_outputs_identical_on_off(self):
        lay, args = _padded_inputs(3, 8, 2, 4, jax.random.PRNGKey(8))
        off = easi_ops.smbgd_probe_bank(*args, block_p=lay.block_p, moments=False)
        on = easi_ops.smbgd_probe_bank(*args, block_p=lay.block_p, moments=True)
        for a, b in zip(off[:-1], on[:-1]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(
            np.asarray(off[-1]), np.zeros((3, 2), np.float32)
        )

    def test_sync_prefetch_bit_identical(self):
        """The double-buffered DMA schedule reorders nothing arithmetic —
        moments included (the interpret path is bit-exact)."""
        lay, args = _padded_inputs(4, 16, 2, 4, jax.random.PRNGKey(9))
        sync = easi_ops.smbgd_step_bank(
            *args, block_p=lay.block_p, prefetch=False, moments=True
        )
        pref = easi_ops.smbgd_step_bank(
            *args, block_p=lay.block_p, prefetch=True, moments=True
        )
        for a, b in zip(sync, pref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_bank_step_identical_with_moments(self):
        """Bank layer: a ``moments=True`` bank commits the identical state
        trajectory (B/Ĥ/step/conv) as a ``moments=False`` one — the leaf is
        pure telemetry on BOTH execution paths."""
        for fused in (False, True):
            ecfg, ocfg = _cfgs()
            plain = SeparatorBank(ecfg, ocfg, n_streams=3, fused=fused)
            teled = SeparatorBank(
                ecfg, ocfg, n_streams=3, fused=fused, moments=True
            )
            s0p = plain.init(jax.random.PRNGKey(1))
            s0t = teled.init(jax.random.PRNGKey(1))
            X = jax.random.normal(jax.random.PRNGKey(2), (3, 8, 4))
            for _ in range(3):
                s0p, _ = plain.step(s0p, X)
                s0t, _ = teled.step(s0t, X)
            for leaf in ("B", "H_hat", "step", "conv"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(s0p, leaf)),
                    np.asarray(getattr(s0t, leaf)),
                )
            assert np.all(np.asarray(s0t.moments) > 0)

    def test_bank_fused_matches_vmap_moments(self):
        """The in-kernel fold ≡ the vmap fallback's whole-array fold."""
        ecfg, ocfg = _cfgs(n=2, m=4)
        fused = SeparatorBank(ecfg, ocfg, n_streams=3, fused=True, moments=True)
        vmapb = SeparatorBank(ecfg, ocfg, n_streams=3, fused=False, moments=True)
        sf = fused.init(jax.random.PRNGKey(4))
        sv = vmapb.init(jax.random.PRNGKey(4))
        X = jax.random.normal(jax.random.PRNGKey(5), (3, 8, 4))
        sf, _ = fused.step(sf, X)
        sv, _ = vmapb.step(sv, X)
        np.testing.assert_allclose(
            np.asarray(sf.moments), np.asarray(sv.moments), rtol=1e-5, atol=1e-6
        )


# ---------------------------------------------------------------------------
# the host-side controller
# ---------------------------------------------------------------------------
def _feed(ctrl, sid, kappa, ticks=1):
    """Feed ``ticks`` telemetry pairs with exact kurtosis ``kappa``:
    Σy² = N makes κ = N·Σy⁴/(Σy²)² = Σy⁴/N."""
    out = 1.0
    for _ in range(ticks):
        out = ctrl.observe(sid, ctrl.count, kappa * ctrl.count)
    return out


class TestMomentController:
    def test_policy_validation(self):
        with pytest.raises(ValueError, match="ema_slow"):
            MomentPolicy(ema_fast=0.1, ema_slow=0.5)
        with pytest.raises(ValueError, match="warmup"):
            MomentPolicy(warmup_ticks=0)
        with pytest.raises(ValueError, match="gain"):
            MomentPolicy(gain=0.0)
        with pytest.raises(ValueError, match="include 1.0"):
            MomentPolicy(min_scale=2.0, max_scale=4.0)
        with pytest.raises(ValueError, match="deadband"):
            MomentPolicy(deadband=-0.1)
        with pytest.raises(ValueError, match="count"):
            MomentController(MomentPolicy(), count=0)

    def test_warmup_holds_scale_at_one(self):
        ctrl = MomentController(MomentPolicy(warmup_ticks=6, deadband=0.0), 16)
        _feed(ctrl, "a", 9.0, ticks=1)  # seeds both EMAs
        for _ in range(4):  # ticks 2..5 < warmup, despite a huge deviation
            assert _feed(ctrl, "a", 1.0) == 1.0
        assert _feed(ctrl, "a", 1.0) > 1.0  # tick 6 crosses warmup

    def test_deadband_pins_steady_state(self):
        """A converged session's κ jitter inside the deadband NEVER moves μ —
        the scale is exactly 1.0, not 1.0±ε."""
        ctrl = MomentController(
            MomentPolicy(warmup_ticks=2, deadband=0.15), 16
        )
        rng = np.random.default_rng(0)
        for _ in range(50):
            assert _feed(ctrl, "a", 4.0 * (1 + 0.02 * rng.standard_normal())) == 1.0

    def test_drift_boosts_and_clamps(self):
        pol = MomentPolicy(
            warmup_ticks=2, deadband=0.05, ema_fast=0.9, ema_slow=1e-4,
            max_scale=3.0,
        )
        ctrl = MomentController(pol, 16)
        _feed(ctrl, "a", 9.0, ticks=3)  # super-Gaussian reference
        s = _feed(ctrl, "a", 3.0, ticks=5)  # CLT drags κ to Gaussian
        fast, slow = ctrl.estimate("a")
        assert fast < slow  # fast EMA left the reference
        assert s > 1.0
        s = _feed(ctrl, "a", 0.01, ticks=10)  # absurd deviation → clamp
        assert s == 3.0

    def test_sub_gaussian_drift_also_boosts(self):
        """Symmetric response: sub-Gaussian sources drift κ UP toward 3."""
        pol = MomentPolicy(
            warmup_ticks=2, deadband=0.05, ema_fast=0.9, ema_slow=1e-4
        )
        ctrl = MomentController(pol, 16)
        _feed(ctrl, "a", 1.5, ticks=3)
        assert _feed(ctrl, "a", 3.0, ticks=5) > 1.0

    def test_anneals_back_to_one(self):
        """Re-convergence pulls the slow reference to the new κ and the
        scale anneals to exactly 1.0 — the fixed boost cannot do this."""
        pol = MomentPolicy(
            warmup_ticks=2, deadband=0.1, ema_fast=0.5, ema_slow=0.2
        )
        ctrl = MomentController(pol, 16)
        _feed(ctrl, "a", 9.0, ticks=4)
        assert _feed(ctrl, "a", 3.0, ticks=3) > 1.0  # mid-drift: boosted
        assert _feed(ctrl, "a", 3.0, ticks=60) == 1.0  # re-converged: annealed

    def test_activity_floor_and_nonfinite_ignored(self):
        ctrl = MomentController(MomentPolicy(warmup_ticks=1), 16)
        assert ctrl.observe("a", 0.0, 0.0) == 1.0  # frozen slot: all-zero row
        assert len(ctrl) == 0  # ...never even seeds a session
        _feed(ctrl, "a", 4.0, ticks=3)
        before = ctrl.estimate("a")
        assert ctrl.observe("a", float("nan"), 1.0) == ctrl.scale("a")
        assert ctrl.observe("a", 16.0, float("inf")) == ctrl.scale("a")
        assert ctrl.estimate("a") == before  # garbage ticks fold nothing

    def test_state_dict_roundtrip(self):
        pol = MomentPolicy(warmup_ticks=2, ema_fast=0.5, ema_slow=0.1)
        ctrl = MomentController(pol, 16)
        _feed(ctrl, "a", 9.0, ticks=4)
        _feed(ctrl, "a", 3.0, ticks=2)
        _feed(ctrl, 7, 2.0, ticks=3)  # non-string session id
        blob = ctrl.state_dict()
        import json

        blob = json.loads(json.dumps(blob))  # must survive JSON
        ctrl2 = MomentController(pol, 16)
        ctrl2.load_state_dict(blob, key_map={"a": "a", "7": 7})
        for sid in ("a", 7):
            assert ctrl2.scale(sid) == ctrl.scale(sid)
            assert ctrl2.estimate(sid) == ctrl.estimate(sid)
        # the restored EMAs keep evolving identically
        assert _feed(ctrl, "a", 3.0) == _feed(ctrl2, "a", 3.0)

    def test_reset_reseeds_reference(self):
        pol = MomentPolicy(warmup_ticks=2, ema_fast=0.9, ema_slow=1e-4,
                           deadband=0.05)
        ctrl = MomentController(pol, 16)
        _feed(ctrl, "a", 9.0, ticks=3)
        assert _feed(ctrl, "a", 3.0, ticks=5) > 1.0
        ctrl.reset("a")
        assert ctrl.scale("a") == 1.0
        # the next tick re-seeds both EMAs at the CURRENT κ: no stale
        # reference, no spurious boost
        assert _feed(ctrl, "a", 3.0) == 1.0
        assert ctrl.estimate("a") == (3.0, 3.0)


# ---------------------------------------------------------------------------
# service composition: cut wins, boost × controller multiply
# ---------------------------------------------------------------------------
def _moment_svc(S=2, P=8, moment_policy=None, **kw):
    ecfg, ocfg = _cfgs(P=P)
    return SeparationService(
        SeparatorBank(ecfg, ocfg, n_streams=S, moments=True),
        moment_policy=(
            moment_policy if moment_policy is not None else MomentPolicy()
        ),
        **kw,
    )


class TestMuComposition:
    """The PR-9 composition bugfix: the three μ ladders keep DISJOINT state;
    ``μ_eff = cut_on ? cut : boost · ctrl`` and one ladder expiring can never
    clobber another's live multiplier."""

    def test_moment_policy_requires_moments_bank(self):
        ecfg, ocfg = _cfgs()
        with pytest.raises(ValueError, match="moments=True"):
            SeparationService(
                SeparatorBank(ecfg, ocfg, n_streams=2),
                moment_policy=MomentPolicy(),
            )

    def test_boost_and_controller_multiply(self):
        svc = _moment_svc()
        svc._boost_scale[0] = 4.0
        svc._ctrl_scale[0] = 2.0
        np.testing.assert_allclose(svc._effective_mu_scale(), [8.0, 1.0])

    def test_cut_wins_while_live(self):
        svc = _moment_svc()
        svc._boost_scale[0] = 4.0
        svc._ctrl_scale[0] = 2.0
        svc._cut_scale[0] = 0.25
        svc._cut_on[0] = True
        np.testing.assert_allclose(svc._effective_mu_scale(), [0.25, 1.0])
        # cut expiring (the ladder clears ITS OWN state only) re-exposes the
        # still-live boost × controller product — nothing was clobbered
        svc._cut_scale[0] = 1.0
        svc._cut_on[0] = False
        np.testing.assert_allclose(svc._effective_mu_scale(), [8.0, 1.0])

    def test_boost_expiry_preserves_controller(self):
        svc = _moment_svc()
        svc._boost_scale[0] = 4.0
        svc._ctrl_scale[0] = 2.0
        svc._boost_scale[0] = 1.0  # what _apply_policy's expiry now does
        np.testing.assert_allclose(svc._effective_mu_scale(), [2.0, 1.0])

    def test_effective_scale_reaches_the_kernel_mu_row(self):
        svc = _moment_svc()
        svc._ctrl_scale[0] = 2.5
        hp = svc._current_hp()
        base = float(svc.bank.opt.mu)
        np.testing.assert_allclose(
            np.asarray(hp.mu), [base * 2.5, base], rtol=1e-6
        )

    def test_lifecycle_carries_all_ladders(self, tmp_path):
        from repro.checkpoint.checkpointer import Checkpointer

        svc = _moment_svc()
        svc.admit("a", source=ReplaySource(
            np.random.default_rng(0).standard_normal((64, 4)).astype(np.float32),
            loop=True,
        ))
        for _ in range(4):
            svc.run_tick()
        svc._boost_scale[0] = 4.0
        svc._ctrl_scale[0] = 2.0
        svc._cut_scale[1] = 0.5
        life = svc.lifecycle
        assert life["mu_boost_scale"] == [4.0, 1.0]
        assert life["mu_ctrl_scale"] == [2.0, 1.0]
        assert life["mu_cut_scale"] == [1.0, 0.5]
        assert life["mu_cut_on"] == [False, False]
        assert life["mu_scale"] == [8.0, 1.0]  # legacy composite view
        assert "a" in life["moments"] or str("a") in life["moments"]
        # full service round-trip: ladders AND controller EMAs survive
        import json

        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=1)
        svc2 = _moment_svc()
        svc2.restore(ckpt, lifecycle=json.loads(json.dumps(life)))
        np.testing.assert_allclose(svc2._boost_scale, svc._boost_scale)
        np.testing.assert_allclose(svc2._cut_scale, svc._cut_scale)
        np.testing.assert_allclose(svc2._ctrl_scale, svc._ctrl_scale)
        np.testing.assert_array_equal(svc2._cut_on, svc._cut_on)
        assert svc2._moments.estimate("a") == svc._moments.estimate("a")

    def test_restore_rejects_controller_state_without_policy(self, tmp_path):
        from repro.checkpoint.checkpointer import Checkpointer

        svc = _moment_svc()
        svc.admit("a", source=ReplaySource(
            np.random.default_rng(0).standard_normal((64, 4)).astype(np.float32),
            loop=True,
        ))
        for _ in range(3):
            svc.run_tick()
        ckpt = Checkpointer(tmp_path)
        svc.save(ckpt, step=0)
        ecfg, ocfg = _cfgs()
        bare = SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=2, moments=True)
        )
        with pytest.raises(ValueError, match="moment-controller"):
            bare.restore(ckpt, lifecycle=svc.lifecycle)


# ---------------------------------------------------------------------------
# end-to-end: the controller reacts to a real distribution change
# ---------------------------------------------------------------------------
class TestServiceAdaptiveMu:
    def test_controller_observes_served_sessions(self):
        svc = _moment_svc(
            moment_policy=MomentPolicy(warmup_ticks=10, deadband=0.5)
        )
        rng = np.random.default_rng(1)
        svc.admit("a", source=ReplaySource(
            rng.standard_normal((64, 4)).astype(np.float32), loop=True
        ))
        for _ in range(5):
            svc.run_tick()
        stats = svc.session_stats("a")
        assert stats["mu_ctrl"] == 1.0  # still inside warmup: never scales
        assert stats["kurtosis_fast"] > 0 and stats["kurtosis_slow"] > 0
        assert len(svc._moments) == 1
        svc.evict("a")
        assert len(svc._moments) == 0  # eviction forgets the EMAs

    def test_distribution_change_scales_mu(self):
        """An abrupt source-statistics change (rademacher → gaussian, i.e.
        sub-Gaussian mixture drifting toward Gaussian) drives the fast κ EMA
        off the reference and μ above base — then annealing begins."""
        P = 64
        ecfg, ocfg = _cfgs(P=P, mu=1e-5)  # tiny μ: B is essentially frozen
        svc = SeparationService(
            SeparatorBank(ecfg, ocfg, n_streams=1, moments=True),
            moment_policy=MomentPolicy(
                ema_fast=0.4, ema_slow=0.01, warmup_ticks=4,
                deadband=0.05, gain=2.0,
            ),
        )
        rng = np.random.default_rng(7)
        flat = rng.choice([-1.0, 1.0], size=(30 * P, 4)).astype(np.float32)
        gauss = rng.standard_normal((30 * P, 4)).astype(np.float32)
        svc.admit("a", source=ReplaySource(np.concatenate([flat, gauss])))
        for _ in range(30):
            svc.run_tick()
        assert svc.session_stats("a")["mu_ctrl"] == 1.0  # pre-drift: steady
        peak = 1.0
        for _ in range(25):
            svc.run_tick()
            peak = max(peak, svc.session_stats("a")["mu_ctrl"])
        assert peak > 1.1  # the controller fired on the κ shift
        hp = svc._current_hp()
        assert float(np.asarray(hp.mu)[0]) >= float(svc.bank.opt.mu)
