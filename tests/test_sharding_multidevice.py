"""Multi-device validation of ``make_sharded_bank_step``.

Runs only with ≥ 8 devices — CI invokes this file separately under

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_sharding_multidevice.py

(the flag must be set before jax initializes, hence the dedicated pytest
invocation; in the ordinary 1-device suite these tests skip).  Asserts that
an 8-way stream-sharded bank step — vmap path, PR-1 Pallas path, fused
megakernel, heterogeneous hyperparams — matches the unsharded bank
bit-for-bit-to-float-tolerance per shard.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.easi import EASIConfig
from repro.core.smbgd import SMBGDConfig
from repro.stream import BankHyperparams, SeparatorBank, bank_sharding, make_sharded_bank_step

N_DEV = 8

pytestmark = [
    pytest.mark.multidevice,
    pytest.mark.skipif(
        jax.device_count() < N_DEV,
        reason=f"needs {N_DEV} devices (XLA_FLAGS=--xla_force_host_platform_device_count={N_DEV})",
    ),
]


def _cfgs(P=8, n=2, m=4):
    return (
        EASIConfig(n_components=n, n_features=m, mu=2e-3),
        SMBGDConfig(batch_size=P, mu=2e-3, beta=0.9, gamma=0.5),
    )


def _mesh():
    return jax.make_mesh((N_DEV,), ("stream",))


@pytest.mark.parametrize(
    "kwargs",
    [
        dict(),
        dict(use_pallas=True),
        dict(fused=True),
    ],
    ids=["vmap", "pallas_grad", "fused_megakernel"],
)
def test_8dev_sharded_step_matches_unsharded(kwargs):
    ecfg, ocfg = _cfgs()
    S = 2 * N_DEV  # 2 local streams per device
    bank = SeparatorBank(ecfg, ocfg, n_streams=S, **kwargs)
    key = jax.random.PRNGKey(0)
    state = bank.init(key)
    X = jax.random.normal(jax.random.fold_in(key, 1), (S, 8, 4))
    if bank.fused:
        X = bank.pad_batch(X)
    mesh = _mesh()
    placed = jax.device_put(state, bank_sharding(mesh))
    sharded_step = make_sharded_bank_step(bank, mesh)
    st_sh, Y_sh = sharded_step(placed, X)
    st_lo, Y_lo = bank.step(state, X)
    # per-shard (= per-stream) equality against the unsharded program
    np.testing.assert_allclose(
        np.asarray(st_sh.B), np.asarray(st_lo.B), rtol=1e-6, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(st_sh.H_hat), np.asarray(st_lo.H_hat), rtol=1e-6, atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(st_sh.step), np.asarray(st_lo.step))
    np.testing.assert_allclose(np.asarray(Y_sh), np.asarray(Y_lo), rtol=1e-6, atol=1e-6)
    # the convergence statistic shards with its streams and matches exactly
    np.testing.assert_allclose(
        np.asarray(st_sh.conv), np.asarray(st_lo.conv), rtol=1e-6, atol=1e-7
    )
    # the state really is laid out over 8 devices
    assert len(st_sh.B.sharding.device_set) == N_DEV


def test_8dev_hetero_hyperparams_shard_with_streams():
    """Per-stream (μ, β, γ) must travel with their streams, not replicate."""
    ecfg, ocfg = _cfgs()
    S = 2 * N_DEV
    key = jax.random.PRNGKey(3)
    hp = BankHyperparams(
        mu=1e-3 + 2e-3 * jax.random.uniform(key, (S,)),
        beta=0.8 + 0.19 * jax.random.uniform(jax.random.fold_in(key, 1), (S,)),
        gamma=0.7 * jax.random.uniform(jax.random.fold_in(key, 2), (S,)),
    )
    bank = SeparatorBank(ecfg, ocfg, n_streams=S, fused=True, hyperparams=hp)
    state = bank.init(key)
    X = bank.pad_batch(jax.random.normal(jax.random.fold_in(key, 4), (S, 8, 4)))
    sharded_step = make_sharded_bank_step(bank, _mesh())
    st_sh, _ = sharded_step(jax.device_put(state, bank_sharding(_mesh())), X)
    st_lo, _ = bank.step(state, X)
    np.testing.assert_allclose(
        np.asarray(st_sh.B), np.asarray(st_lo.B), rtol=1e-6, atol=1e-6
    )


def test_8dev_active_mask_and_multiple_steps():
    """A 3-tick sharded trajectory with a changing active mask matches the
    unsharded bank (the serving scenario on a device rack)."""
    ecfg, ocfg = _cfgs()
    S = 2 * N_DEV
    bank = SeparatorBank(ecfg, ocfg, n_streams=S, fused=True)
    key = jax.random.PRNGKey(5)
    mesh = _mesh()
    sharded_step = make_sharded_bank_step(bank, mesh, donate=False)
    st_sh = jax.device_put(bank.init(key), bank_sharding(mesh))
    st_lo = bank.init(key)
    for k in range(3):
        X = bank.pad_batch(
            jax.random.normal(jax.random.fold_in(key, 10 + k), (S, 8, 4))
        )
        active = (jnp.arange(S) % (k + 2) != 0)
        st_sh, _ = sharded_step(st_sh, X, active)
        st_lo, _ = bank.step(st_lo, X, active=active)
    np.testing.assert_allclose(
        np.asarray(st_sh.B), np.asarray(st_lo.B), rtol=1e-6, atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(st_sh.step), np.asarray(st_lo.step))
