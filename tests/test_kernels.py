"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode — CPU container; TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.nonlinearities import NONLINEARITIES
from repro.kernels.easi_gradient.easi_gradient import NONLIN_KERNELS
from repro.kernels.easi_gradient.ops import easi_gradient, easi_gradient_bank
from repro.kernels.easi_gradient.ref import easi_gradient_bank_ref, easi_gradient_ref
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.smbgd_update.ops import smbgd_update
from repro.kernels.smbgd_update.ref import smbgd_update_ref


class TestEASIGradientKernel:
    @pytest.mark.parametrize("P,n", [(8, 2), (64, 2), (1000, 4), (513, 17), (4096, 64), (256, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, P, n, dtype):
        key = jax.random.PRNGKey(P * 1000 + n)
        Y = jax.random.normal(key, (P, n), dtype)
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (P,)))
        S_k = easi_gradient(Y, w)
        S_r = easi_gradient_ref(Y, w)
        tol = 5e-3 if dtype == jnp.bfloat16 else 2e-3
        scale = max(1.0, float(jnp.max(jnp.abs(S_r))))
        assert float(jnp.max(jnp.abs(S_k - S_r))) < tol * scale

    @pytest.mark.parametrize("nl", ["cubic", "tanh", "relu", "scaled_tanh"])
    def test_all_nonlinearities(self, nl):
        key = jax.random.PRNGKey(0)
        Y = jax.random.normal(key, (128, 8))
        w = jnp.ones((128,)) * 1e-3
        np.testing.assert_allclose(
            np.asarray(easi_gradient(Y, w, nonlinearity=nl)),
            np.asarray(easi_gradient_ref(Y, w, nonlinearity=nl)),
            rtol=1e-4, atol=1e-5,
        )

    @given(P=st.integers(1, 300), n=st.integers(2, 32))
    @settings(max_examples=15, deadline=None)
    def test_property_random_shapes(self, P, n):
        """Padding must be exact for arbitrary (P, n)."""
        key = jax.random.PRNGKey(P * 37 + n)
        Y = jax.random.normal(key, (P, n))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (P,))) * 0.01
        S_k = easi_gradient(Y, w)
        S_r = easi_gradient_ref(Y, w)
        scale = max(1.0, float(jnp.max(jnp.abs(S_r))))
        assert float(jnp.max(jnp.abs(S_k - S_r))) < 1e-3 * scale

    def test_nonlin_table_is_core_registry(self):
        """The kernel nonlinearity bank must BE the core registry (satellite:
        the hand-copied table let `relu` drift once already)."""
        assert NONLIN_KERNELS is NONLINEARITIES

    def test_aligned_fast_path_bit_identical(self):
        """Block-aligned inputs skip the zeros().at[].set() staging copy —
        the fast path must be bit-identical to the padding path's math.
        (Aligned here means P divisible by the block and n sublane-aligned in
        interpret mode; (513, 17) in the sweep above covers the slow path.)"""
        key = jax.random.PRNGKey(42)
        Y = jax.random.normal(key, (256, 8))  # aligned: P % block == 0, n % 8 == 0
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (256,))) * 0.01
        np.testing.assert_array_equal(
            np.asarray(easi_gradient(Y, w, block_p=64)),
            np.asarray(easi_gradient(jnp.pad(Y, ((0, 0), (0, 0))), w, block_p=64)),
        )
        # and it still matches the oracle (i.e. the skip really fed the kernel
        # the same operands, not a stale/transposed view)
        S_r = easi_gradient_ref(Y, w)
        assert float(jnp.max(jnp.abs(easi_gradient(Y, w, block_p=64) - S_r))) < 1e-3
        # bank form
        Yb = jax.random.normal(jax.random.fold_in(key, 2), (3, 256, 8))
        S_k = easi_gradient_bank(Yb, w, block_p=64)
        S_rb = easi_gradient_bank_ref(Yb, w)
        scale = max(1.0, float(jnp.max(jnp.abs(S_rb))))
        assert float(jnp.max(jnp.abs(S_k - S_rb))) < 1e-3 * scale


class TestEASIGradientBankKernel:
    """The (streams, P-tiles) batched grid: one launch folds all streams."""

    @pytest.mark.parametrize("S,P,n", [(1, 64, 2), (4, 64, 2), (3, 513, 17), (8, 100, 7)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_bank_oracle(self, S, P, n, dtype):
        key = jax.random.PRNGKey(S * 10_000 + P * 10 + n)
        Y = jax.random.normal(key, (S, P, n), dtype)
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (P,))) * 0.1
        S_k = easi_gradient_bank(Y, w)
        S_r = easi_gradient_bank_ref(Y, w)
        tol = 5e-3 if dtype == jnp.bfloat16 else 2e-3
        scale = max(1.0, float(jnp.max(jnp.abs(S_r))))
        assert float(jnp.max(jnp.abs(S_k - S_r))) < tol * scale

    def test_streams_bit_identical_to_single_launches(self):
        """Each stream's slice must equal a single-stream launch with the same
        block geometry — the bank grid adds no numerical difference."""
        key = jax.random.PRNGKey(0)
        Y = jax.random.normal(key, (5, 200, 6))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (200,))) * 0.01
        bank = easi_gradient_bank(Y, w)
        singles = jnp.stack([easi_gradient(Y[s], w) for s in range(5)])
        np.testing.assert_array_equal(np.asarray(bank), np.asarray(singles))

    @pytest.mark.parametrize("nl", sorted(NONLINEARITIES))
    def test_all_nonlinearities(self, nl):
        key = jax.random.PRNGKey(1)
        Y = jax.random.normal(key, (3, 128, 8))
        w = jnp.ones((128,)) * 1e-3
        np.testing.assert_allclose(
            np.asarray(easi_gradient_bank(Y, w, nonlinearity=nl)),
            np.asarray(easi_gradient_bank_ref(Y, w, nonlinearity=nl)),
            rtol=1e-4, atol=1e-5,
        )


class TestSMBGDUpdateKernel:
    @pytest.mark.parametrize("n,m", [(2, 4), (2, 2), (16, 33), (64, 600), (7, 1025)])
    def test_matches_oracle(self, n, m):
        key = jax.random.PRNGKey(n * 100 + m)
        H = jax.random.normal(key, (n, n)) * 0.1
        S = jax.random.normal(jax.random.fold_in(key, 1), (n, n)) * 0.1
        B = jax.random.normal(jax.random.fold_in(key, 2), (n, m))
        for gamma in (0.0, 0.45, 0.99):
            Hk, Bk = smbgd_update(gamma, H, S, B)
            Hr, Br = smbgd_update_ref(gamma, H, S, B)
            np.testing.assert_allclose(np.asarray(Hk), np.asarray(Hr), rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(np.asarray(Bk), np.asarray(Br), rtol=1e-5, atol=1e-5)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 2), (8, 1)])
    @pytest.mark.parametrize(
        "opts",
        [
            dict(causal=True),
            dict(causal=False),
            dict(causal=True, window=64),
            dict(causal=True, softcap=30.0),
            dict(causal=True, window=32, softcap=50.0),
        ],
    )
    def test_matches_oracle(self, Hq, Hkv, opts):
        B, T, d = 2, 256, 64
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (B, Hq, T, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, T, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, T, d))
        o_k = flash_attention_pallas(q, k, v, scale=d**-0.5, block_q=64, block_k=64, **opts)
        o_r = attention_ref(q, k, v, scale=d**-0.5, **opts)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), rtol=2e-4, atol=2e-5)

    def test_bf16_inputs_fp32_softmax(self):
        B, H, T, d = 1, 2, 128, 64
        key = jax.random.PRNGKey(1)
        q = jax.random.normal(key, (B, H, T, d), jnp.bfloat16)
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, T, d), jnp.bfloat16)
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, T, d), jnp.bfloat16)
        o_k = flash_attention_pallas(q, k, v, scale=d**-0.5, block_q=64, block_k=64)
        o_r = attention_ref(q, k, v, scale=d**-0.5)
        assert o_k.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(o_k, dtype=np.float32), np.asarray(o_r, dtype=np.float32),
            rtol=5e-2, atol=5e-2,
        )

    def test_block_shape_invariance(self):
        """Different BlockSpec tilings must give identical results."""
        B, H, T, d = 1, 2, 256, 64
        key = jax.random.PRNGKey(2)
        q = jax.random.normal(key, (B, H, T, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, T, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, T, d))
        o1 = flash_attention_pallas(q, k, v, scale=0.125, block_q=64, block_k=64)
        o2 = flash_attention_pallas(q, k, v, scale=0.125, block_q=128, block_k=32)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-6)
