"""Whole-step fused SMBGD bank megakernel vs the vmap'd oracle.

The megakernel's correctness claim: ONE (streams, P-tiles) launch on
persistent padded state reproduces, to float tolerance, the vmap'd
``smbgd_batched_step`` math (shared hyperparams) and the hetero-vmap fallback
(per-stream μ, β, γ) — including the step-0 γ gate, active-mask freezing, and
multi-step trajectories where padding junk must never leak into the logical
block.  The kernel-level sweep checks ``ops.smbgd_step_bank`` against the
deliberately naive per-stream loop oracle in ``ref.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import smbgd as smbgd_lib
from repro.core.easi import EASIConfig
from repro.core.nonlinearities import NONLINEARITIES
from repro.core.smbgd import SMBGDConfig
from repro.kernels.easi_gradient import ops as easi_ops
from repro.kernels.easi_gradient.ref import smbgd_step_bank_ref
from repro.stream import BankHyperparams, SeparatorBank


def _cfgs(P=8, n=2, m=4, mu=2e-3, beta=0.9, gamma=0.5, nonlinearity="cubic",
          dtype=jnp.float32):
    return (
        EASIConfig(n_components=n, n_features=m, mu=mu,
                   nonlinearity=nonlinearity, dtype=dtype),
        SMBGDConfig(batch_size=P, mu=mu, beta=beta, gamma=gamma),
    )


def _hetero(S, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return BankHyperparams(
        mu=1e-3 + 2e-3 * jax.random.uniform(k1, (S,)),
        beta=0.7 + 0.29 * jax.random.uniform(k2, (S,)),
        gamma=0.8 * jax.random.uniform(k3, (S,)),
    )


class TestMegakernelVsRefOracle:
    """ops.smbgd_step_bank against the naive per-stream loop in ref.py."""

    @pytest.mark.parametrize("S,P,n,m", [(1, 8, 2, 4), (4, 32, 8, 8), (3, 16, 2, 6)])
    def test_matches_ref(self, S, P, n, m):
        lay = easi_ops.bank_layout(n, m, P)
        assert lay.P_pad % lay.block_p == 0
        assert lay.n_pad % 8 == 0 and lay.m_pad % 8 == 0  # interpret sublane
        assert lay.n_pad >= n and lay.m_pad >= m and lay.P_pad >= P
        key = jax.random.PRNGKey(S * 1000 + P * 10 + n)
        # build persistent-layout inputs with real content in the logical block
        X = jnp.zeros((S, lay.P_pad, lay.m_pad)).at[:, :P, :m].set(
            jax.random.normal(key, (S, P, m))
        )
        B = jnp.zeros((S, lay.n_pad, lay.m_pad)).at[:, :n, :m].set(
            jax.random.normal(jax.random.fold_in(key, 1), (S, n, m)) * 0.3
        )
        H = jnp.zeros((S, lay.n_pad, lay.n_pad)).at[:, :n, :n].set(
            jax.random.normal(jax.random.fold_in(key, 2), (S, n, n)) * 0.1
        )
        W = jnp.zeros((S, lay.P_pad)).at[:, :P].set(
            jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (S, P))) * 0.01
        )
        step = jnp.arange(S, dtype=jnp.int32)  # stream 0 is at step 0 (γ gate)
        gamma_hat = 0.1 + 0.8 * jax.random.uniform(jax.random.fold_in(key, 4), (S,))
        active = (jnp.arange(S) % 3 != 2).astype(jnp.int32)  # freeze every 3rd
        conv0 = jnp.arange(1.0, S + 1.0)  # distinct: frozen carry is visible
        Y, B2, H2, s2, c2, h2, _mom = easi_ops.smbgd_step_bank(
            X, W, B, H, step, gamma_hat, active, conv0, block_p=lay.block_p
        )
        Yr, Br, Hr, sr, cr, hr, _momr = smbgd_step_bank_ref(
            X, W, B, H, step, gamma_hat, active, conv0
        )
        np.testing.assert_array_equal(np.asarray(h2), np.asarray(hr))
        np.testing.assert_allclose(np.asarray(Y), np.asarray(Yr), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(B2), np.asarray(Br), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(H2), np.asarray(Hr), rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(s2), np.asarray(sr))
        np.testing.assert_allclose(np.asarray(c2), np.asarray(cr), rtol=1e-5, atol=1e-6)
        # frozen streams carry their previous statistic through unchanged
        np.testing.assert_array_equal(
            np.asarray(c2)[np.asarray(active) == 0],
            np.asarray(conv0)[np.asarray(active) == 0],
        )

    def test_block_p_tiling_invariance(self):
        """Different P-tile sizes fold the same sum — results must agree."""
        S, P, n, m = 3, 64, 8, 8
        key = jax.random.PRNGKey(0)
        X = jax.random.normal(key, (S, P, m))
        B = jax.random.normal(jax.random.fold_in(key, 1), (S, n, m)) * 0.3
        H = jax.random.normal(jax.random.fold_in(key, 2), (S, n, n)) * 0.1
        W = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (S, P))) * 0.01
        step = jnp.ones((S,), jnp.int32)
        gamma_hat = jnp.full((S,), 0.4)
        active = jnp.ones((S,), jnp.int32)
        outs = [
            easi_ops.smbgd_step_bank(X, W, B, H, step, gamma_hat, active, block_p=bp)
            for bp in (8, 16, 64)
        ]
        for o in outs[1:]:
            for a, b in zip(outs[0], o):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6
                )

    def test_rejects_unaligned_inputs(self):
        """The hot path must refuse to silently pad (boundary discipline)."""
        with pytest.raises(ValueError, match="persistent-layout"):
            easi_ops.smbgd_step_bank(
                jnp.zeros((2, 7, 8)),  # P=7 not tileable
                jnp.zeros((2, 7)),
                jnp.zeros((2, 8, 8)),
                jnp.zeros((2, 8, 8)),
                jnp.zeros((2,), jnp.int32),
                jnp.zeros((2,)),
                jnp.ones((2,), jnp.int32),
            )


@pytest.mark.property
class TestMegakernelPropertySweep:
    """Hypothesis sweep: ``ops.smbgd_step_bank`` against the naive per-stream
    ref oracle over random (S, P, n, m, block_p, block_s, nonlinearity,
    hetero-vs-uniform hyperparams) — including ragged logical shapes that
    exercise the pad/unpad boundaries, random active masks, and mixed step
    counters (the γ step-0 gate)."""

    @staticmethod
    def _padded_inputs(lay, S, P, n, m, key):
        """Persistent-layout tensors with real content only in the logical
        block (padding must stay exactly zero — the kernel's contract)."""
        X = jnp.zeros((S, lay.P_pad, lay.m_pad)).at[:, :P, :m].set(
            jax.random.normal(key, (S, P, m))
        )
        B = jnp.zeros((S, lay.n_pad, lay.m_pad)).at[:, :n, :m].set(
            jax.random.normal(jax.random.fold_in(key, 1), (S, n, m)) * 0.3
        )
        H = jnp.zeros((S, lay.n_pad, lay.n_pad)).at[:, :n, :n].set(
            jax.random.normal(jax.random.fold_in(key, 2), (S, n, n)) * 0.1
        )
        return X, B, H

    @given(
        S=st.integers(1, 6),
        P=st.integers(1, 40),
        n=st.integers(2, 12),
        m_extra=st.integers(0, 5),
        block_p=st.sampled_from([8, 16, 32]),
        block_s_req=st.integers(1, 4),
        nonlinearity=st.sampled_from(sorted(NONLINEARITIES)),
        hetero=st.sampled_from([False, True]),
    )
    @settings(max_examples=12, deadline=None)
    def test_kernel_matches_ref_oracle(
        self, S, P, n, m_extra, block_p, block_s_req, nonlinearity, hetero
    ):
        m = n + m_extra
        lay = easi_ops.bank_layout(n, m, P, block_p=block_p)
        assert lay.P_pad % lay.block_p == 0 and lay.P_pad >= P
        # largest divisor of S ≤ the requested stream block
        block_s = max(b for b in range(1, block_s_req + 1) if S % b == 0)
        key = jax.random.PRNGKey(S * 7919 + P * 101 + n * 13 + m_extra)
        X, B, H = self._padded_inputs(lay, S, P, n, m, key)
        if hetero:
            hp = _hetero(S, jax.random.fold_in(key, 9))
        else:
            hp = BankHyperparams.broadcast(
                SMBGDConfig(batch_size=max(P, 1), mu=2e-3, beta=0.9, gamma=0.5), S
            )
        W = jnp.zeros((S, lay.P_pad)).at[:, :P].set(hp.within_batch_weights(P))
        gamma_hat = hp.effective_momentum(P)
        step = jax.random.randint(jax.random.fold_in(key, 3), (S,), 0, 3)
        active = jax.random.bernoulli(jax.random.fold_in(key, 4), 0.7, (S,)).astype(
            jnp.int32
        )
        conv0 = jax.random.uniform(jax.random.fold_in(key, 5), (S,)) + 0.5
        out_k = easi_ops.smbgd_step_bank(
            X, W, B, H, step, gamma_hat, active, conv0,
            nonlinearity=nonlinearity, block_p=lay.block_p, block_s=block_s,
        )
        out_r = smbgd_step_bank_ref(
            X, W, B, H, step, gamma_hat, active, conv0, nonlinearity=nonlinearity
        )
        names = ("Y", "B", "H_hat", "step", "conv", "health", "moments")
        for name, a, b in zip(names, out_k, out_r):
            if name in ("step", "health"):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            else:
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5,
                    err_msg=f"{name} S={S} P={P} n={n} m={m} bp={block_p} "
                    f"bs={block_s} g={nonlinearity} hetero={hetero}",
                )
        # padded B region must stay exactly zero (persistent-state contract)
        pad_B = np.array(out_k[1])
        pad_B[:, :n, :m] = 0.0
        np.testing.assert_array_equal(pad_B, np.zeros_like(pad_B))

    @given(
        S=st.integers(1, 5),
        P=st.integers(2, 24),
        n=st.integers(2, 9),
        nonlinearity=st.sampled_from(sorted(NONLINEARITIES)),
        hetero=st.sampled_from([False, True]),
    )
    @settings(max_examples=10, deadline=None)
    def test_all_paths_report_identical_conv_stats(
        self, S, P, n, nonlinearity, hetero
    ):
        """The acceptance bar: fused / pallas / vmap / hetero bank steps all
        report the same per-stream convergence statistic as the ref oracle."""
        m = n + 2
        ecfg, ocfg = _cfgs(P=P, n=n, m=m, nonlinearity=nonlinearity)
        key = jax.random.PRNGKey(S * 1009 + P * 31 + n)
        hp = _hetero(S, jax.random.fold_in(key, 9)) if hetero else None
        banks = {
            "fused": SeparatorBank(
                ecfg, ocfg, n_streams=S, fused=True, hyperparams=hp
            ),
            "hetero_vmap": SeparatorBank(ecfg, ocfg, n_streams=S, hyperparams=hp)
            if hetero
            else SeparatorBank(
                ecfg, ocfg, n_streams=S,
                hyperparams=BankHyperparams.broadcast(ocfg, S),
            ),
        }
        if not hetero:  # these two paths take shared scalar hyperparams only
            banks["vmap"] = SeparatorBank(ecfg, ocfg, n_streams=S)
            banks["pallas"] = SeparatorBank(ecfg, ocfg, n_streams=S, use_pallas=True)
        st0 = SeparatorBank(ecfg, ocfg, n_streams=S).init(key)
        X = jax.random.normal(jax.random.fold_in(key, 1), (S, P, m))
        convs = {}
        for name, bank in banks.items():
            state = bank.pad_state(st0) if bank.fused else st0
            new_state, _ = bank.step(state, X)
            convs[name] = np.asarray(new_state.conv)
            assert convs[name].shape == (S,)
        # ref oracle on the logical shapes with the same per-stream weights
        ehp = hp if hp is not None else BankHyperparams.broadcast(ocfg, S)
        _, _, _, _, conv_ref, _, _ = smbgd_step_bank_ref(
            X,
            ehp.within_batch_weights(P),
            st0.B,
            st0.H_hat,
            st0.step,
            ehp.effective_momentum(P),
            jnp.ones((S,), jnp.int32),
            nonlinearity=nonlinearity,
        )
        conv_ref = np.asarray(conv_ref)
        for name, c in convs.items():
            np.testing.assert_allclose(
                c, conv_ref, rtol=1e-4, atol=1e-5,
                err_msg=f"path={name} S={S} P={P} n={n} g={nonlinearity} "
                f"hetero={hetero}",
            )


class TestFusedBankVsVmapOracle:
    """SeparatorBank(fused=True) against the vmap reference paths."""

    @pytest.mark.parametrize(
        "S,P,n,m,nonlinearity",
        [
            (1, 8, 2, 4, "cubic"),
            (5, 8, 2, 4, "tanh"),
            (3, 13, 3, 5, "cubic"),      # odd P and m: real padding
            (4, 32, 17, 17, "relu"),     # n > sublane, odd
            (2, 16, 2, 9, "scaled_tanh"),
        ],
    )
    @pytest.mark.parametrize("hetero", [False, True])
    def test_multistep_trajectory_matches(self, S, P, n, m, nonlinearity, hetero):
        """3-step trajectories (persistent padded state carried across steps)
        must match the vmap oracle — shared and per-stream hyperparams."""
        ecfg, ocfg = _cfgs(P=P, n=n, m=m, nonlinearity=nonlinearity)
        key = jax.random.PRNGKey(S * 100 + P)
        hp = _hetero(S, jax.random.fold_in(key, 9)) if hetero else None
        ref = SeparatorBank(ecfg, ocfg, n_streams=S, hyperparams=hp)
        fused = SeparatorBank(ecfg, ocfg, n_streams=S, fused=True, hyperparams=hp)
        st_r, st_f = ref.init(key), fused.init(key)
        fstep = jax.jit(fused.step)
        for k in range(3):
            X = jax.random.normal(jax.random.fold_in(key, k), (S, P, m))
            st_r, Y_r = ref.step(st_r, X)
            st_f, Y_f = fstep(st_f, X)
            u = fused.unpad_state(st_f)
            assert float(jnp.max(jnp.abs(u.B - st_r.B))) <= 1e-5
            assert float(jnp.max(jnp.abs(u.H_hat - st_r.H_hat))) <= 1e-5
            assert float(jnp.max(jnp.abs(fused.unpad_y(Y_f) - Y_r))) <= 1e-5
            np.testing.assert_array_equal(np.asarray(u.step), np.asarray(st_r.step))

    @pytest.mark.property
    @given(S=st.integers(1, 6), P=st.integers(1, 40), n=st.integers(2, 12))
    @settings(max_examples=10, deadline=None)
    def test_property_random_shapes(self, S, P, n):
        """Padding must be exact for arbitrary (S, P, n) — one fused step."""
        m = n + 2
        ecfg, ocfg = _cfgs(P=P, n=n, m=m)
        key = jax.random.PRNGKey(S * 1000 + P * 13 + n)
        ref = SeparatorBank(ecfg, ocfg, n_streams=S)
        fused = SeparatorBank(ecfg, ocfg, n_streams=S, fused=True)
        st0 = ref.init(key)
        X = jax.random.normal(jax.random.fold_in(key, 1), (S, P, m))
        st_r, Y_r = ref.step(st0, X)
        st_f, Y_f = fused.step(fused.pad_state(st0), X)
        u = fused.unpad_state(st_f)
        assert float(jnp.max(jnp.abs(u.B - st_r.B))) <= 1e-5
        assert float(jnp.max(jnp.abs(fused.unpad_y(Y_f) - Y_r))) <= 1e-5

    def test_step0_gamma_gate_per_stream(self):
        """A stream at step 0 must ignore a poisoned momentum buffer even
        while its neighbour (step 5) applies it — inside the megakernel.
        (health_checks off: the drill NEEDS the blown update to commit.)"""
        ecfg, ocfg = _cfgs(P=4, gamma=0.9)
        bank = SeparatorBank(
            ecfg, ocfg, n_streams=2, fused=True, health_checks=False
        )
        key = jax.random.PRNGKey(0)
        state = bank.init(key)
        lay = bank.layout
        poisoned = state.H_hat.at[:, : lay.n, : lay.n].set(1e3)
        state = state._replace(H_hat=poisoned, step=state.step.at[1].set(5))
        X = jax.random.normal(jax.random.fold_in(key, 1), (2, 4, 4))
        new_state, _ = bank.step(state, X)
        u = bank.unpad_state(new_state)
        st0 = smbgd_lib.init_state(ecfg, jax.random.split(key, 2)[0])
        ref, _ = smbgd_lib.smbgd_batched_step(
            st0._replace(
                B=bank.unpad_state(state).B[0], H_hat=bank.unpad_state(state).H_hat[0]
            ),
            X[0],
            ecfg,
            ocfg,
        )
        np.testing.assert_allclose(np.asarray(u.B[0]), np.asarray(ref.B), atol=1e-5)
        assert float(jnp.max(jnp.abs(u.B[1] - bank.unpad_state(state).B[1]))) > 1.0

    def test_active_mask_freezes_in_kernel(self):
        ecfg, ocfg = _cfgs(P=4)
        bank = SeparatorBank(ecfg, ocfg, n_streams=4, fused=True)
        key = jax.random.PRNGKey(0)
        state = bank.init(key)
        X = jax.random.normal(jax.random.fold_in(key, 1), (4, 4, 4))
        active = jnp.array([True, False, True, False])
        new_state, _ = bank.step(state, X, active=active)
        for s, a in enumerate(active):
            same = bool(jnp.all(new_state.B[s] == state.B[s]))
            stepped = int(new_state.step[s]) == int(state.step[s]) + 1
            assert same != bool(a)
            assert stepped == bool(a)

    def test_epoch_matches_vmap_epoch(self):
        ecfg, ocfg = _cfgs(P=8)
        S, T = 6, 128
        key = jax.random.PRNGKey(3)
        X = jax.random.normal(jax.random.fold_in(key, 1), (S, T, 4))
        ref = SeparatorBank(ecfg, ocfg, n_streams=S)
        fused = SeparatorBank(ecfg, ocfg, n_streams=S, fused=True)
        st_r, Y_r = ref.epoch(ref.init(key), X)
        st_f, Y_f = jax.jit(fused.epoch)(fused.init(key), X)
        u = fused.unpad_state(st_f)
        assert Y_f.shape == Y_r.shape  # epoch returns logical Y
        assert float(jnp.max(jnp.abs(u.B - st_r.B))) <= 1e-5
        assert float(jnp.max(jnp.abs(Y_f - Y_r))) <= 1e-5

    @pytest.mark.parametrize("nl", sorted(NONLINEARITIES))
    def test_all_nonlinearities_single_step(self, nl):
        ecfg, ocfg = _cfgs(P=8, nonlinearity=nl)
        S = 3
        key = jax.random.PRNGKey(11)
        ref = SeparatorBank(ecfg, ocfg, n_streams=S)
        fused = SeparatorBank(ecfg, ocfg, n_streams=S, fused=True)
        st0 = ref.init(key)
        X = jax.random.normal(jax.random.fold_in(key, 1), (S, 8, 4))
        st_r, Y_r = ref.step(st0, X)
        st_f, Y_f = fused.step(fused.pad_state(st0), X)
        assert float(jnp.max(jnp.abs(fused.unpad_state(st_f).B - st_r.B))) <= 1e-5
        assert float(jnp.max(jnp.abs(fused.unpad_y(Y_f) - Y_r))) <= 1e-5

    def test_bf16_state_within_tolerance(self):
        ecfg, ocfg = _cfgs(P=8, dtype=jnp.bfloat16)
        S = 4
        key = jax.random.PRNGKey(5)
        ref = SeparatorBank(ecfg, ocfg, n_streams=S)
        fused = SeparatorBank(ecfg, ocfg, n_streams=S, fused=True)
        st0 = ref.init(key)
        X = jax.random.normal(jax.random.fold_in(key, 1), (S, 8, 4), jnp.bfloat16)
        st_r, _ = ref.step(st0, X)
        st_f, _ = fused.step(fused.pad_state(st0), X)
        u = fused.unpad_state(st_f)
        assert u.B.dtype == jnp.bfloat16
        assert float(
            jnp.max(jnp.abs(u.B.astype(jnp.float32) - st_r.B.astype(jnp.float32)))
        ) <= 5e-2


class TestPersistentPaddedState:
    """The zero-copy serving contract around the megakernel."""

    def test_init_is_padded_and_logical_equal(self):
        ecfg, ocfg = _cfgs(P=13, n=3, m=5)
        ref = SeparatorBank(ecfg, ocfg, n_streams=4)
        fused = SeparatorBank(ecfg, ocfg, n_streams=4, fused=True)
        lay = fused.layout
        key = jax.random.PRNGKey(0)
        st = fused.init(key)
        assert st.B.shape == (4, lay.n_pad, lay.m_pad)
        assert st.H_hat.shape == (4, lay.n_pad, lay.n_pad)
        np.testing.assert_array_equal(
            np.asarray(fused.unpad_state(st).B), np.asarray(ref.init(key).B)
        )
        # pad/unpad round-trip is exact
        rt = fused.pad_state(fused.unpad_state(st))
        np.testing.assert_array_equal(np.asarray(rt.B), np.asarray(st.B))

    def test_prepadded_batch_is_bit_identical(self):
        """Staging X block-aligned (the serving fast path) must produce the
        same bits as handing the bank a logical X to pad."""
        ecfg, ocfg = _cfgs(P=13, n=3, m=5)
        bank = SeparatorBank(ecfg, ocfg, n_streams=3, fused=True)
        key = jax.random.PRNGKey(1)
        state = bank.init(key)
        X = jax.random.normal(jax.random.fold_in(key, 1), (3, 13, 5))
        st_a, Y_a = bank.step(state, X)
        st_b, Y_b = bank.step(state, bank.pad_batch(X))
        for a, b in zip(st_a, st_b):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(Y_a), np.asarray(Y_b))

    def test_donated_steps_match_undonated(self):
        """Buffer donation must be semantics-free over a long trajectory."""
        ecfg, ocfg = _cfgs(P=8)
        bank = SeparatorBank(ecfg, ocfg, n_streams=4, fused=True)
        key = jax.random.PRNGKey(2)
        step_d = bank.make_step(donate=True)
        step_u = bank.make_step(donate=False)
        st_d, st_u = bank.init(key), bank.init(key)
        act = jnp.ones((4,), bool)
        for k in range(6):
            X = bank.pad_batch(jax.random.normal(jax.random.fold_in(key, k), (4, 8, 4)))
            st_d, Y_d = step_d(st_d, X, act)
            st_u, Y_u = step_u(st_u, X, act)
        np.testing.assert_array_equal(np.asarray(st_d.B), np.asarray(st_u.B))
        np.testing.assert_array_equal(np.asarray(st_d.H_hat), np.asarray(st_u.H_hat))

    def test_padding_junk_never_leaks(self):
        """Whatever accumulates in the padded region (the Σw identity diag)
        must stay there: logical block identical to the vmap run after many
        steps, and padded B region exactly zero."""
        ecfg, ocfg = _cfgs(P=5, n=2, m=3)  # heavy padding
        ref = SeparatorBank(ecfg, ocfg, n_streams=2)
        fused = SeparatorBank(ecfg, ocfg, n_streams=2, fused=True)
        lay = fused.layout
        key = jax.random.PRNGKey(7)
        st_r, st_f = ref.init(key), fused.init(key)
        fstep = jax.jit(fused.step)
        for k in range(20):
            X = jax.random.normal(jax.random.fold_in(key, k), (2, 5, 3)) * 0.5
            st_r, _ = ref.step(st_r, X)
            st_f, _ = fstep(st_f, X)
        u = fused.unpad_state(st_f)
        assert float(jnp.max(jnp.abs(u.B - st_r.B))) <= 1e-4
        pad_B = np.array(st_f.B)
        pad_B[:, : lay.n, : lay.m] = 0.0
        np.testing.assert_array_equal(pad_B, np.zeros_like(pad_B))

    def test_slot_lifecycle_on_padded_bank(self):
        """init_slot clears the whole padded slot; slot_state unpads."""
        ecfg, ocfg = _cfgs(P=8)
        bank = SeparatorBank(ecfg, ocfg, n_streams=3, fused=True)
        key = jax.random.PRNGKey(4)
        state = bank.init(key)
        # run a few steps so H_hat's padded diagonal carries Σw junk
        for k in range(3):
            state, _ = bank.step(
                state, jax.random.normal(jax.random.fold_in(key, k), (3, 8, 4))
            )
        state = bank.init_slot(state, 1, jax.random.fold_in(key, 99))
        np.testing.assert_array_equal(
            np.asarray(state.H_hat[1]), np.zeros_like(np.asarray(state.H_hat[1]))
        )
        sub = bank.slot_state(state, 1)
        assert sub.B.shape == (2, 4) and int(sub.step) == 0

    def test_fused_requires_batched_algorithm(self):
        ecfg, ocfg = _cfgs()
        with pytest.raises(ValueError, match="fused"):
            SeparatorBank(ecfg, ocfg, n_streams=2, algorithm="sgd", fused=True)

    def test_hyperparams_shape_validated(self):
        ecfg, ocfg = _cfgs()
        bad = BankHyperparams(
            mu=jnp.ones((3,)), beta=jnp.ones((3,)), gamma=jnp.zeros((3,))
        )
        with pytest.raises(ValueError, match="hyperparams"):
            SeparatorBank(ecfg, ocfg, n_streams=2, hyperparams=bad)


class TestHeterogeneousBank:
    """Per-stream (μ, β, γ) — ROADMAP's scaling-limit sweep in one launch."""

    def test_stream_matches_its_own_config(self):
        """Stream s of a hetero bank must follow exactly the trajectory of a
        homogeneous bank configured with stream s's scalars."""
        ecfg, _ = _cfgs()
        S = 4
        key = jax.random.PRNGKey(0)
        mus = [1e-3, 2e-3, 4e-3, 8e-3]
        hp = BankHyperparams(
            mu=jnp.asarray(mus),
            beta=jnp.full((S,), 0.9),
            gamma=jnp.full((S,), 0.5),
        )
        ocfg = SMBGDConfig(batch_size=8, mu=2e-3, beta=0.9, gamma=0.5)
        hetero = SeparatorBank(ecfg, ocfg, n_streams=S, fused=True, hyperparams=hp)
        st_h = hetero.init(key)
        X = jax.random.normal(jax.random.fold_in(key, 1), (S, 8, 4))
        for k in range(3):
            st_h, _ = hetero.step(st_h, X)
        u = hetero.unpad_state(st_h)
        keys = jax.random.split(key, S)
        for s, mu in enumerate(mus):
            ocfg_s = SMBGDConfig(batch_size=8, mu=mu, beta=0.9, gamma=0.5)
            st_s = smbgd_lib.init_state(ecfg, keys[s])
            for k in range(3):
                st_s, _ = smbgd_lib.smbgd_batched_step(st_s, X[s], ecfg, ocfg_s)
            assert float(jnp.max(jnp.abs(u.B[s] - st_s.B))) <= 1e-5, s

    def test_gamma_zero_stream_has_no_momentum(self):
        """γ_s = 0 must kill cross-batch momentum for that stream only."""
        ecfg, ocfg = _cfgs(P=4)
        hp = BankHyperparams(
            mu=jnp.full((2,), 2e-3),
            beta=jnp.full((2,), 0.9),
            gamma=jnp.asarray([0.0, 0.9]),
        )
        bank = SeparatorBank(ecfg, ocfg, n_streams=2, fused=True, hyperparams=hp)
        key = jax.random.PRNGKey(1)
        state = bank.init(key)
        X = jax.random.normal(jax.random.fold_in(key, 1), (2, 4, 4))
        state, _ = bank.step(state, X)  # step 0: γ gated for both
        u1 = bank.unpad_state(state)
        state2, _ = bank.step(state, X)  # step 1: γ live for stream 1 only
        u2 = bank.unpad_state(state2)
        # stream 0: H carries only the fresh gradient sum (no momentum term) —
        # identical X ⇒ S changes only through B; compare against γ=0 oracle
        ocfg0 = SMBGDConfig(batch_size=4, mu=2e-3, beta=0.9, gamma=0.0)
        st_s = smbgd_lib.init_state(ecfg, jax.random.split(key, 2)[0])
        for _ in range(2):
            st_s, _ = smbgd_lib.smbgd_batched_step(st_s, X[0], ecfg, ocfg0)
        assert float(jnp.max(jnp.abs(u2.B[0] - st_s.B))) <= 1e-5
        assert not np.allclose(np.asarray(u2.H_hat[1]), np.asarray(u1.H_hat[1]))
