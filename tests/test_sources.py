"""Signal sources: the pluggable feeds behind ``SeparationService.run_tick``.

Covers the ``SignalSource`` protocol contract ((m, n_samples) channel-major
blocks, exhaustion, cursors) and each adapter: ``SyntheticSource`` parity
with ``MixedSignals``, drift windows, ``ReplaySource`` determinism/looping,
``ChannelBankSource`` windowed + memory-mapped ``.npy`` reads."""
import numpy as np
import pytest

import jax

from repro.data.pipeline import MixedSignals
from repro.data import signals
from repro.data.sources import (
    ChannelBankSource,
    ReplaySource,
    SignalSource,
    SourceExhausted,
    SyntheticSource,
    true_mixing_of,
)


class TestSyntheticSource:
    def _pipe(self, **kw):
        base = dict(m=4, n=2, batch=8, seed=0)
        base.update(kw)
        return MixedSignals(**base)

    def test_blocks_are_channel_major_and_deterministic(self):
        a = SyntheticSource(self._pipe())
        b = SyntheticSource(self._pipe())
        x1, x2 = a.next_block(8), a.next_block(8)
        assert x1.shape == (4, 8) and x1.dtype == np.float32
        assert not np.array_equal(x1, x2)  # the cursor advanced
        np.testing.assert_array_equal(b.next_block(8), x1)  # replayable
        np.testing.assert_array_equal(b.next_block(8), x2)

    def test_matches_mixed_signals_stream(self):
        """With no drift window, blocks are exactly the pipe's per-stream
        mini-batches (the adapter adds a cursor, not new data)."""
        pipe = self._pipe(streams=3, drift_rate=2e-4)
        src = SyntheticSource(pipe, stream=1)
        for step in range(4):
            expected = np.asarray(pipe.batch_for_step(step))[1]  # (P, m)
            np.testing.assert_allclose(
                src.next_block(8), expected.T, rtol=1e-6, atol=1e-6
            )
        np.testing.assert_allclose(
            np.asarray(src.true_mixing()),
            np.asarray(pipe.mixing_at(4, stream=1)),
            rtol=1e-6,
            atol=1e-6,
        )

    def test_multi_stream_pipe_requires_stream(self):
        with pytest.raises(ValueError, match="stream"):
            SyntheticSource(self._pipe(streams=2))

    def test_wrong_block_size_rejected(self):
        src = SyntheticSource(self._pipe(batch=8))
        with pytest.raises(ValueError, match="fixed blocks"):
            src.next_block(16)

    def test_drift_window_holds_then_rotates_then_settles(self):
        pipe = self._pipe(drift_rate=1e-2)
        src = SyntheticSource(pipe, drift_start=3, drift_stop=6)
        A_pre = src.true_mixing()
        for _ in range(3):
            src.next_block(8)
        np.testing.assert_array_equal(src.true_mixing(), A_pre)  # pre-onset
        for _ in range(3):
            src.next_block(8)
        A_post = src.true_mixing()
        assert np.abs(A_post - A_pre).max() > 1e-3  # rotated
        for _ in range(5):
            src.next_block(8)
        np.testing.assert_array_equal(src.true_mixing(), A_post)  # settled

    def test_drift_stop_before_start_rejected(self):
        with pytest.raises(ValueError, match="drift_stop"):
            SyntheticSource(self._pipe(drift_rate=1e-3), drift_start=5, drift_stop=4)

    def test_seek_resumes_exactly(self):
        src = SyntheticSource(self._pipe())
        blocks = [src.next_block(8) for _ in range(4)]
        assert src.position == 32
        src.seek(16)
        np.testing.assert_array_equal(src.next_block(8), blocks[2])
        with pytest.raises(ValueError, match="multiple"):
            src.seek(13)

    def test_protocol_conformance(self):
        src = SyntheticSource(self._pipe())
        assert isinstance(src, SignalSource)
        assert true_mixing_of(src).shape == (4, 2)


class TestReplaySource:
    def test_sequential_blocks_then_exhausted(self):
        X = np.arange(20, dtype=np.float32).reshape(10, 2)
        src = ReplaySource(X)
        b1 = src.next_block(4)
        assert b1.shape == (2, 4)
        np.testing.assert_array_equal(b1, X[:4].T)
        np.testing.assert_array_equal(src.next_block(4), X[4:8].T)
        with pytest.raises(SourceExhausted):
            src.next_block(4)  # only 2 samples left
        src.reset()
        np.testing.assert_array_equal(src.next_block(4), X[:4].T)

    def test_loop_wraps(self):
        X = np.arange(12, dtype=np.float32).reshape(6, 2)
        src = ReplaySource(X, loop=True)
        for _ in range(3):
            src.next_block(4)  # wraps without raising
        assert src.position <= 6

    def test_mixing_static_and_per_sample(self):
        X = np.zeros((6, 4), np.float32)
        A = np.eye(4)[:, :2]
        assert true_mixing_of(ReplaySource(X)) is None
        np.testing.assert_array_equal(
            ReplaySource(X, mixing=A).true_mixing(), A
        )
        At = np.stack([A * (t + 1) for t in range(6)])
        src = ReplaySource(X, mixing=At)
        np.testing.assert_array_equal(src.true_mixing(), At[0])
        src.next_block(3)
        np.testing.assert_array_equal(src.true_mixing(), At[3])
        with pytest.raises(ValueError, match="per-sample mixing"):
            ReplaySource(X, mixing=At[:4])

    def test_rejects_non_2d(self):
        with pytest.raises(ValueError, match=r"\(T, m\)"):
            ReplaySource(np.zeros((4, 2, 2)))

    def test_blocks_are_copies(self):
        """Serving mutates staging buffers; replay blocks must be detached."""
        X = np.zeros((8, 2), np.float32)
        src = ReplaySource(X)
        blk = src.next_block(4)
        blk[:] = 99.0
        assert X.max() == 0.0


class TestChannelBankSource:
    def _recording(self, tmp_path, C=6, T=64, layout="ct"):
        rng = np.random.default_rng(0)
        data = rng.normal(size=(C, T)).astype(np.float32)
        path = tmp_path / "rec.npy"
        np.save(path, data if layout == "ct" else data.T)
        return path, data

    def test_windowed_reads_match_file(self, tmp_path):
        path, data = self._recording(tmp_path)
        src = ChannelBankSource(path, center=False)
        np.testing.assert_allclose(src.next_block(16), data[:, :16])
        np.testing.assert_allclose(src.next_block(16), data[:, 16:32])
        assert src.position == 32 and src.n_channels == 6

    def test_tc_layout_equivalent(self, tmp_path):
        path_ct, data = self._recording(tmp_path)
        np.save(tmp_path / "rec_tc.npy", np.load(path_ct).T)
        a = ChannelBankSource(path_ct, center=False)
        b = ChannelBankSource(tmp_path / "rec_tc.npy", layout="tc", center=False)
        np.testing.assert_allclose(a.next_block(16), b.next_block(16))

    def test_channel_selection(self, tmp_path):
        path, data = self._recording(tmp_path)
        src = ChannelBankSource(path, channels=[4, 0, 2], center=False)
        assert src.n_channels == 3
        np.testing.assert_allclose(src.next_block(8), data[[4, 0, 2], :8])
        with pytest.raises(ValueError, match="channels"):
            ChannelBankSource(path, channels=[99])

    def test_mmap_vs_loaded_identical(self, tmp_path):
        path, _ = self._recording(tmp_path)
        a = ChannelBankSource(path, mmap=True)
        b = ChannelBankSource(path, mmap=False)
        np.testing.assert_array_equal(a.next_block(16), b.next_block(16))

    def test_center_removes_window_mean(self, tmp_path):
        path, _ = self._recording(tmp_path)
        blk = ChannelBankSource(path, center=True).next_block(32)
        np.testing.assert_allclose(blk.mean(axis=1), 0.0, atol=1e-6)

    def test_exhaustion_and_loop(self, tmp_path):
        path, _ = self._recording(tmp_path, T=40)
        src = ChannelBankSource(path)
        src.next_block(32)
        with pytest.raises(SourceExhausted, match="drained"):
            src.next_block(16)
        looping = ChannelBankSource(path, loop=True)
        for _ in range(5):
            assert looping.next_block(16).shape == (6, 16)

    def test_accepts_in_memory_array(self):
        data = np.random.default_rng(1).normal(size=(3, 20)).astype(np.float32)
        src = ChannelBankSource(data, center=False)
        np.testing.assert_allclose(src.next_block(10), data[:, :10])

    def test_layout_and_ndim_validated(self, tmp_path):
        path, _ = self._recording(tmp_path)
        with pytest.raises(ValueError, match="layout"):
            ChannelBankSource(path, layout="cc")
        with pytest.raises(ValueError, match="2-D"):
            ChannelBankSource(np.zeros((2, 3, 4)))

    def test_true_mixing_absent(self, tmp_path):
        path, _ = self._recording(tmp_path)
        assert true_mixing_of(ChannelBankSource(path)) is None


class TestMixingAsSource:
    """A ReplaySource built from ``drifting_mixing_matrix`` +
    ``mix_nonstationary`` is the signals-module route to a ground-truth-aware
    drifting feed (what the drift benchmark replays)."""

    def test_replay_of_nonstationary_mix(self):
        key = jax.random.PRNGKey(0)
        At = signals.drifting_mixing_matrix(key, 4, 2, 64, rate=1e-3)
        S = signals.source_bank(jax.random.PRNGKey(1), 2, 64)
        X = signals.mix_nonstationary(At, S)
        src = ReplaySource(np.asarray(X), mixing=np.asarray(At))
        blk = src.next_block(16)
        assert blk.shape == (4, 16)
        np.testing.assert_allclose(blk, np.asarray(X[:16]).T, rtol=1e-6)
        np.testing.assert_allclose(
            src.true_mixing(), np.asarray(At[16]), rtol=1e-6
        )
