"""Data pipelines: determinism, rank-disjointness, elastic invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.registry import get_config
from repro.data.pipeline import MixedSignals, SyntheticLM, make_lm_pipeline
from repro.data import signals


class TestSyntheticLM:
    def _pipe(self, **kw):
        base = dict(vocab_size=1000, seq_len=64, global_batch=8, seed=3)
        base.update(kw)
        return SyntheticLM(**base)

    def test_deterministic(self):
        p = self._pipe()
        a = p.batch_for_step(5)["tokens"]
        b = p.batch_for_step(5)["tokens"]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_steps_differ(self):
        p = self._pipe()
        a = p.batch_for_step(5)["tokens"]
        b = p.batch_for_step(6)["tokens"]
        assert not np.array_equal(np.asarray(a), np.asarray(b))

    @given(dp=st.sampled_from([1, 2, 4, 8]))
    @settings(max_examples=4, deadline=None)
    def test_elastic_invariance(self, dp):
        """The global stream must not depend on dp_size (restart at a new
        cluster size sees the same data)."""
        p = self._pipe()
        full = p.batch_for_step(9, 0, 1)["tokens"]
        parts = [p.batch_for_step(9, r, dp)["tokens"] for r in range(dp)]
        np.testing.assert_array_equal(
            np.asarray(full), np.asarray(jnp.concatenate(parts, axis=0))
        )

    def test_rank_disjoint(self):
        p = self._pipe()
        a = p.batch_for_step(2, 0, 2)["tokens"]
        b = p.batch_for_step(2, 1, 2)["tokens"]
        assert not np.array_equal(np.asarray(a), np.asarray(b))

    def test_tokens_in_range_and_learnable_structure(self):
        p = self._pipe()
        t = np.asarray(p.batch_for_step(0)["tokens"])
        assert t.min() >= 0 and t.max() < 1000
        # bigram structure: odd positions are a deterministic fn of evens
        nxt = (t[:, 0::2] * 31 + 7) % 1000
        assert np.array_equal(t[:, 1::2], nxt[:, : t[:, 1::2].shape[1]])

    def test_modality_variants(self):
        mg = make_lm_pipeline(get_config("musicgen-large").reduced(), 32, 4)
        b = mg.batch_for_step(0)
        assert b["tokens"].shape == (4, 32, 4)
        vl = make_lm_pipeline(get_config("internvl2-76b").reduced(), 32, 4)
        b = vl.batch_for_step(0)
        assert b["tokens"].shape == (4, 32 - 8)
        assert b["vision_embeds"].shape == (4, 8, 64)


class TestMixedSignals:
    def test_deterministic_and_elastic(self):
        p = MixedSignals(m=4, n=2, batch=8, seed=0)
        a = p.batch_for_step(3)
        b = p.batch_for_step(3)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        parts = [p.batch_for_step(3, r, 2) for r in range(2)]
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(jnp.concatenate(parts, axis=0))
        )

    def test_drift_changes_mixing(self):
        p = MixedSignals(m=4, n=2, batch=8, seed=0, drift_rate=1e-3)
        A0 = p.mixing_at(0)
        A1 = p.mixing_at(500)
        assert float(jnp.max(jnp.abs(A0 - A1))) > 1e-2

    def test_stationary_mixing_constant(self):
        p = MixedSignals(m=4, n=2, batch=8, seed=0, drift_rate=0.0)
        np.testing.assert_array_equal(
            np.asarray(p.mixing_at(0)), np.asarray(p.mixing_at(999))
        )


class TestSignalBank:
    def test_sources_zero_mean_unit_var(self):
        S = signals.source_bank(jax.random.PRNGKey(0), 4, 20_000)
        m = np.asarray(jnp.mean(S, axis=0))
        v = np.asarray(jnp.std(S, axis=0))
        np.testing.assert_allclose(m, 0, atol=1e-2)
        np.testing.assert_allclose(v, 1, atol=1e-2)

    def test_sources_sub_gaussian(self):
        """Cubic-nonlinearity EASI needs negative-kurtosis sources."""
        S = np.asarray(signals.source_bank(jax.random.PRNGKey(1), 4, 50_000))
        kurt = ((S**4).mean(0) / (S**2).mean(0) ** 2) - 3.0
        assert (kurt < 0).all(), kurt

    def test_mixing_matrix_conditioned(self):
        A = signals.random_mixing_matrix(jax.random.PRNGKey(2), 6, 3)
        s = np.linalg.svd(np.asarray(A), compute_uv=False)
        assert s[-1] > 0.05 * s[0]

    def test_nonstationary_mix_shapes(self):
        At = signals.drifting_mixing_matrix(jax.random.PRNGKey(3), 4, 2, 100)
        S = signals.source_bank(jax.random.PRNGKey(4), 2, 100)
        X = signals.mix_nonstationary(At, S)
        assert X.shape == (100, 4)


class TestDriftingMixing:
    """``drifting_mixing_matrix``/``mix_nonstationary``: rotation-rate
    correctness and determinism — the ground truth the drift pipeline's
    watchdog is measured against."""

    def test_rotation_rate_is_exact(self):
        """A(t) must equal R(rate·t)·A(0) — rotation by exactly ``rate``
        radians per step in the (0, 1) plane."""
        rate, T = 3e-3, 200
        At = np.asarray(
            signals.drifting_mixing_matrix(jax.random.PRNGKey(0), 4, 2, T, rate=rate)
        )
        for t in (1, 57, T - 1):
            theta = rate * t
            R = np.eye(4, dtype=np.float32)
            R[0, 0] = R[1, 1] = np.cos(theta)
            R[0, 1], R[1, 0] = -np.sin(theta), np.sin(theta)
            np.testing.assert_allclose(At[t], R @ At[0], rtol=1e-4, atol=1e-5)

    def test_rotation_preserves_conditioning(self):
        """Rotations are orthogonal: singular values of A(t) never change —
        the drifting problem stays exactly as solvable as the original."""
        At = np.asarray(
            signals.drifting_mixing_matrix(jax.random.PRNGKey(1), 4, 2, 300, rate=5e-3)
        )
        sv0 = np.linalg.svd(At[0], compute_uv=False)
        svT = np.linalg.svd(At[-1], compute_uv=False)
        np.testing.assert_allclose(sv0, svT, rtol=1e-4)

    def test_zero_rate_is_stationary(self):
        At = np.asarray(
            signals.drifting_mixing_matrix(jax.random.PRNGKey(2), 4, 2, 50, rate=0.0)
        )
        np.testing.assert_allclose(At, np.broadcast_to(At[0], At.shape), atol=1e-7)

    def test_deterministic_per_seed_distinct_across_seeds(self):
        a1 = np.asarray(signals.drifting_mixing_matrix(jax.random.PRNGKey(7), 4, 2, 40))
        a2 = np.asarray(signals.drifting_mixing_matrix(jax.random.PRNGKey(7), 4, 2, 40))
        b = np.asarray(signals.drifting_mixing_matrix(jax.random.PRNGKey(8), 4, 2, 40))
        np.testing.assert_array_equal(a1, a2)
        assert np.abs(a1 - b).max() > 1e-3

    def test_mix_nonstationary_matches_per_step_matmul(self):
        key = jax.random.PRNGKey(3)
        At = signals.drifting_mixing_matrix(key, 4, 2, 30, rate=1e-2)
        S = signals.source_bank(jax.random.PRNGKey(4), 2, 30)
        X = np.asarray(signals.mix_nonstationary(At, S))
        expected = np.stack(
            [np.asarray(At[t]) @ np.asarray(S[t]) for t in range(30)]
        )
        np.testing.assert_allclose(X, expected, rtol=1e-5, atol=1e-6)

    def test_mix_nonstationary_constant_equals_stationary_mix(self):
        A = signals.random_mixing_matrix(jax.random.PRNGKey(5), 4, 2)
        S = signals.source_bank(jax.random.PRNGKey(6), 2, 25)
        At = jnp.broadcast_to(A, (25, 4, 2))
        np.testing.assert_allclose(
            np.asarray(signals.mix_nonstationary(At, S)),
            np.asarray(signals.mix(A, S)),
            rtol=1e-5,
            atol=1e-6,
        )
