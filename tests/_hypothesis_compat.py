"""Graceful degradation when ``hypothesis`` is not installed.

With hypothesis available the real ``given``/``settings``/``st`` are
re-exported unchanged.  Without it (bare CPU containers), property tests
degrade to deterministic seeded example tests: each strategy exposes a small
list of representative values (always including the boundaries) and ``@given``
runs the test body over a fixed-seed sample of combinations.  Coverage is
thinner than real property testing but the suite still collects and runs.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised implicitly when hypothesis is present
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import inspect
    import random as _random

    HAVE_HYPOTHESIS = False

    _MAX_EXAMPLES = 6  # per @given — seeded, not exhaustive

    class _Strategy:
        def __init__(self, examples):
            self.examples = list(examples)

    class _St:
        """Deterministic stand-ins for the strategies this repo uses."""

        @staticmethod
        def sampled_from(seq):
            return _Strategy(seq)

        @staticmethod
        def integers(min_value, max_value):
            rng = _random.Random(f"int:{min_value}:{max_value}")
            span = max_value - min_value
            vals = {min_value, max_value, min_value + span // 2}
            vals.update(min_value + rng.randrange(span + 1) for _ in range(3))
            return _Strategy(sorted(vals))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            rng = _random.Random(f"float:{min_value}:{max_value}")
            vals = [min_value, max_value, 0.5 * (min_value + max_value)]
            vals += [
                min_value + (max_value - min_value) * rng.random() for _ in range(2)
            ]
            return _Strategy(vals)

    st = _St()

    def settings(*_args, **_kwargs):  # max_examples/deadline are no-ops here
        def deco(fn):
            return fn

        return deco

    def given(**strategies):
        names = sorted(strategies)

        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = _random.Random(0)
                pools = {k: strategies[k].examples for k in names}
                n = min(max(len(p) for p in pools.values()), _MAX_EXAMPLES)
                for i in range(n):
                    # offset each pool by its key index so equal-length pools
                    # aren't paired diagonally (covers cross-boundary combos
                    # like (min, max) instead of only (min, min))
                    chosen = {
                        k: (
                            pools[k][(i + j) % len(pools[k])]
                            if i < n - 1
                            else rng.choice(pools[k])
                        )
                        for j, k in enumerate(names)
                    }
                    fn(*args, **{**kwargs, **chosen})

            # Hide the strategy-supplied params from pytest's fixture
            # resolution (deliberately NOT functools.wraps: __wrapped__ would
            # expose the original signature again).
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[
                    p for p in sig.parameters.values() if p.name not in strategies
                ]
            )
            return wrapper

        return deco
