"""Deterministic convergence regression: fixed-seed ``MixedSignals`` runs per
nonlinearity against CHECKED-IN thresholds and tick budgets.

Numerics tests (kernel == oracle) cannot catch a silent *algorithmic*
regression — a sign flip, a mis-ordered commit, a broken γ gate all keep the
paths mutually consistent while destroying separation.  This suite pins the
behaviour itself: with the repo's synthetic sub-Gaussian sources (sinusoid +
uniform — the paper's §V setup), the separator must push the Amari index
below a checked-in threshold within a checked-in number of mini-batches.

The stability region of an EASI stationary point depends on the source
distribution through Cardoso's nonlinear-moment condition: ``cubic`` and the
signed-``relu`` satisfy it for sub-Gaussian sources and must SEPARATE;
``tanh``/``scaled_tanh`` (super-Gaussian choices) do not, and for them the
checked-in regression is *stability* — the iteration must stay bounded (a
NaN/blow-up regression is the failure mode worth guarding there).

Marked ``slow``: runs in CI's full-matrix job, not the fast default suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EASIConfig, SMBGDConfig, amari_index, global_system
from repro.core.nonlinearities import NONLINEARITIES
from repro.data.pipeline import MixedSignals
from repro.serve.engine import ConvergencePolicy, SeparationService
from repro.stream import Separator, SeparatorBank

pytestmark = pytest.mark.slow

# Checked-in regression budgets: (amari threshold, tick budget) per
# nonlinearity whose stability condition the MixedSignals sources satisfy.
# Measured headroom (seed 0, jax CPU): cubic/relu reach ≈0.02–0.04 by tick
# 250 — a 0.1/500 bar only trips on real regressions, not float drift.
SEPARATES = {
    "cubic": (0.1, 500),
    "relu": (0.1, 500),
}
# Super-Gaussian nonlinearities on sub-Gaussian sources: must stay bounded.
STAYS_BOUNDED = sorted(set(NONLINEARITIES) - set(SEPARATES))


def _run(nonlinearity: str, n_ticks: int):
    ecfg = EASIConfig(n_components=2, n_features=4, mu=3e-3, nonlinearity=nonlinearity)
    ocfg = SMBGDConfig(batch_size=16, mu=3e-3, beta=0.9, gamma=0.5)
    sep = Separator(ecfg, ocfg)
    state = sep.init(jax.random.PRNGKey(0))
    pipe = MixedSignals(m=4, n=2, batch=16, seed=0)
    fit = jax.jit(sep.step)
    for step in range(n_ticks):
        state, _ = fit(state, pipe.batch_for_step(step))
    pi = float(amari_index(global_system(state.B, pipe.mixing_at(n_ticks - 1))))
    return state, pi


@pytest.mark.parametrize("nonlinearity", sorted(SEPARATES))
def test_separating_nonlinearity_converges_within_budget(nonlinearity):
    threshold, budget = SEPARATES[nonlinearity]
    _, pi = _run(nonlinearity, budget)
    assert pi < threshold, (
        f"{nonlinearity}: Amari index {pi:.4f} after {budget} ticks "
        f"(checked-in bar: < {threshold}) — algorithmic regression"
    )


@pytest.mark.parametrize("nonlinearity", STAYS_BOUNDED)
def test_out_of_region_nonlinearity_stays_bounded(nonlinearity):
    state, pi = _run(nonlinearity, 500)
    assert np.all(np.isfinite(np.asarray(state.B))), f"{nonlinearity} diverged"
    assert float(jnp.max(jnp.abs(state.B))) < 1e3, f"{nonlinearity} blew up"
    assert np.isfinite(pi)


def test_bank_conv_statistic_tracks_amari_convergence():
    """End-to-end tie between the tentpole pieces: a fused bank serving real
    separation problems must (a) reach the checked-in Amari bar and (b) show
    it through the in-kernel convergence statistic, which the service's
    policy then turns into an auto-eviction."""
    S, P, budget = 2, 16, 500
    ecfg = EASIConfig(n_components=2, n_features=4, mu=3e-3)
    ocfg = SMBGDConfig(batch_size=P, mu=3e-3, beta=0.9, gamma=0.5)
    # the blind statistic proposes, the registered ground-truth mixing
    # confirms (the policy's amari gate): eviction implies real separation
    policy = ConvergencePolicy(
        threshold=0.02, patience=5, min_ticks=50, ema=0.9, amari_threshold=0.12
    )
    svc = SeparationService(
        SeparatorBank(ecfg, ocfg, n_streams=S, fused=True), seed=0, policy=policy
    )
    pipe = MixedSignals(m=4, n=2, batch=P, seed=0, streams=S)
    sids = [f"s{i}" for i in range(S)]
    A0 = np.asarray(pipe.mixing_at(0))
    for i, sid in enumerate(sids):
        svc.admit(sid)
        svc.set_mixing(sid, A0[i])
    evicted_at = {}
    for tick in range(budget):
        X = np.asarray(pipe.batch_for_step(tick))
        served = [s for s in sids if svc.status(s) == "active"]
        if not served:
            break
        svc.step({sid: X[i] for i, sid in enumerate(sids) if sid in served})
        for sid in sids:
            if sid not in evicted_at and svc.status(sid) == "finished":
                evicted_at[sid] = tick
    assert sorted(evicted_at) == sids, (
        f"conv statistic never crossed the policy threshold within {budget} "
        f"ticks: {svc.lifecycle['monitors']}"
    )
    # the auto-evicted separators really did separate (ground-truth check;
    # guaranteed by the amari gate at decision time — no drift here)
    for i, sid in enumerate(sids):
        B = np.asarray(svc.finished[sid].state.B)
        pi = float(amari_index(global_system(jnp.asarray(B), jnp.asarray(A0[i]))))
        assert pi <= 0.12, f"{sid} evicted unconverged: Amari {pi:.4f}"
