"""SeparatorBank: S-stream equivalence with S independent single-stream runs
(the bank's central correctness claim), algorithm dispatch, admission masking,
stream-axis sharding, checkpoint round-trip, and the streamed data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import easi as easi_lib
from repro.core import smbgd as smbgd_lib
from repro.core.easi import EASIConfig
from repro.core.smbgd import SMBGDConfig
from repro.data.pipeline import MixedSignals
from repro.stream import (
    BankState,
    Separator,
    SeparatorBank,
    bank_sharding,
    make_sharded_bank_step,
)


def _cfgs(P=8, mu=2e-3, beta=0.9, gamma=0.5, n=2, m=4):
    return (
        EASIConfig(n_components=n, n_features=m, mu=mu),
        SMBGDConfig(batch_size=P, mu=mu, beta=beta, gamma=gamma),
    )


class TestSeparatorFrontend:
    """One front-end over the three historical epoch drivers."""

    def test_algorithm_dispatch_matches_drivers(self):
        ecfg, ocfg = _cfgs()
        key = jax.random.PRNGKey(0)
        X = jax.random.normal(key, (64, 4))
        st0 = smbgd_lib.init_state(ecfg, jax.random.PRNGKey(1))

        sep = Separator(ecfg, ocfg, algorithm="smbgd_batched")
        st_a, Y_a = sep.epoch(st0, X)
        st_b, Y_b = smbgd_lib.smbgd_epoch(st0, X, ecfg, ocfg)
        np.testing.assert_array_equal(np.asarray(st_a.B), np.asarray(st_b.B))

        sep = Separator(ecfg, ocfg, algorithm="smbgd_sequential")
        st_a, _ = sep.epoch(st0, X)
        st_b, _ = smbgd_lib.smbgd_epoch_sequential(st0, X, ecfg, ocfg)
        np.testing.assert_array_equal(np.asarray(st_a.B), np.asarray(st_b.B))

        sep = Separator(ecfg, ocfg, algorithm="sgd")
        st_a, _ = sep.epoch(st0, X)
        B_b, _ = easi_lib.easi_sgd_scan(st0.B, X, ecfg)
        np.testing.assert_array_equal(np.asarray(st_a.B), np.asarray(B_b))

    def test_smbgd_alias_and_unknown_rejected(self):
        ecfg, ocfg = _cfgs()
        assert Separator(ecfg, ocfg, algorithm="smbgd").algorithm == "smbgd_batched"
        with pytest.raises(ValueError):
            Separator(ecfg, ocfg, algorithm="newton")


class TestBankEquivalence:
    """A bank of S streams must match S independent single-stream runs."""

    def test_s64_matches_64_independent_epochs(self):
        """The acceptance bar: SeparatorBank(S=64) ≡ 64 × smbgd_epoch ≤ 1e-5."""
        ecfg, ocfg = _cfgs(P=8)
        S, T = 64, 256
        key = jax.random.PRNGKey(7)
        bank = SeparatorBank(ecfg, ocfg, n_streams=S)
        state = bank.init(key)
        # real per-stream separation problems (raw normal data can diverge)
        X = MixedSignals(m=4, n=2, batch=T, seed=0, streams=S).batch_for_step(0)
        state2, Y = bank.epoch(state, X)
        # fused Pallas path must hold the same bar over the full epoch
        state_p, Y_p = jax.jit(
            SeparatorBank(ecfg, ocfg, n_streams=S, use_pallas=True).epoch
        )(state, X)
        keys = jax.random.split(key, S)
        for s in range(S):
            st0 = smbgd_lib.init_state(ecfg, keys[s])
            st1, Y1 = smbgd_lib.smbgd_epoch(st0, X[s], ecfg, ocfg)
            assert float(jnp.max(jnp.abs(st1.B - state2.B[s]))) <= 1e-5
            assert float(jnp.max(jnp.abs(st1.H_hat - state2.H_hat[s]))) <= 1e-5
            assert float(jnp.max(jnp.abs(Y1 - Y[s]))) <= 1e-5
            assert float(jnp.max(jnp.abs(st1.B - state_p.B[s]))) <= 1e-5
            assert float(jnp.max(jnp.abs(Y1 - Y_p[s]))) <= 1e-5

    @pytest.mark.parametrize("algorithm", ["sgd", "smbgd_sequential"])
    def test_other_algorithms_match_independent(self, algorithm):
        ecfg, ocfg = _cfgs(P=4)
        S, T = 6, 64
        key = jax.random.PRNGKey(3)
        bank = SeparatorBank(ecfg, ocfg, n_streams=S, algorithm=algorithm)
        state = bank.init(key)
        X = jax.random.normal(jax.random.fold_in(key, 1), (S, T, 4))
        state2, Y = bank.epoch(state, X)
        keys = jax.random.split(key, S)
        sep = Separator(ecfg, ocfg, algorithm=algorithm)
        for s in range(S):
            st1, Y1 = sep.epoch(sep.init(keys[s]), X[s])
            assert float(jnp.max(jnp.abs(st1.B - state2.B[s]))) <= 1e-5
            assert float(jnp.max(jnp.abs(Y1 - Y[s]))) <= 1e-5

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("P,n,m", [(8, 2, 4), (13, 3, 5), (32, 17, 17)])
    def test_pallas_bank_matches_vmap_path(self, dtype, P, n, m):
        """Fused (streams, tiles) kernel vs the vmapped reference math for one
        bank step, across dtypes and odd (non-lane-aligned) n / odd P padding
        cases.  Single-step on purpose: multi-step trajectories are chaotic
        and amplify bf16 ulps unboundedly (fp32 epochs are compared in
        ``test_s64_matches_64_independent_epochs``)."""
        ecfg = EASIConfig(n_components=n, n_features=m, mu=1e-3, dtype=dtype)
        ocfg = SMBGDConfig(batch_size=P, mu=1e-3, beta=0.9, gamma=0.5)
        S = 5
        key = jax.random.PRNGKey(P * 10 + n)
        X = jax.random.normal(jax.random.fold_in(key, 1), (S, P, m), dtype)
        ref_bank = SeparatorBank(ecfg, ocfg, n_streams=S, use_pallas=False)
        pal_bank = SeparatorBank(ecfg, ocfg, n_streams=S, use_pallas=True)
        state = ref_bank.init(key)
        st_r, Y_r = ref_bank.step(state, X)
        st_p, Y_p = jax.jit(pal_bank.step)(state, X)
        # bf16 has ~2^-8 relative resolution → a few ulps of slack
        tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
        assert float(jnp.max(jnp.abs(st_r.B.astype(jnp.float32) - st_p.B.astype(jnp.float32)))) <= tol
        assert float(jnp.max(jnp.abs(Y_r.astype(jnp.float32) - Y_p.astype(jnp.float32)))) <= tol

    def test_fresh_slot_gamma_gated_independently(self):
        """Per-stream step counters: a freshly admitted stream (step=0) must
        gate γ off even while its neighbours are at step k ≫ 0.
        (health_checks off: the drill NEEDS the blown update to commit.)"""
        ecfg, ocfg = _cfgs(P=4, gamma=0.9)
        bank = SeparatorBank(ecfg, ocfg, n_streams=2, health_checks=False)
        key = jax.random.PRNGKey(0)
        state = bank.init(key)
        # poison both momentum buffers; stream 1 pretends to be at step 5
        state = BankState(
            B=state.B,
            H_hat=jnp.full_like(state.H_hat, 1e3),
            step=state.step.at[1].set(5),
        )
        X = jax.random.normal(jax.random.fold_in(key, 1), (2, 4, 4))
        new_state, _ = bank.step(state, X)
        # stream 0 (step=0): poisoned H ignored → finite, small B
        st0 = smbgd_lib.init_state(ecfg, jax.random.split(key, 2)[0])
        ref, _ = smbgd_lib.smbgd_batched_step(
            st0._replace(B=state.B[0], H_hat=state.H_hat[0]), X[0], ecfg, ocfg
        )
        np.testing.assert_allclose(
            np.asarray(new_state.B[0]), np.asarray(ref.B), atol=1e-6
        )
        # stream 1 (step=5): poisoned H applied → very different B
        assert float(jnp.max(jnp.abs(new_state.B[1] - state.B[1]))) > 1.0

    def test_active_mask_freezes_slots(self):
        ecfg, ocfg = _cfgs(P=4)
        bank = SeparatorBank(ecfg, ocfg, n_streams=4)
        key = jax.random.PRNGKey(0)
        state = bank.init(key)
        X = jax.random.normal(jax.random.fold_in(key, 1), (4, 4, 4))
        active = jnp.array([True, False, True, False])
        new_state, _ = bank.step(state, X, active=active)
        for s, a in enumerate(active):
            same = bool(jnp.all(new_state.B[s] == state.B[s]))
            stepped = int(new_state.step[s]) == int(state.step[s]) + 1
            assert same != bool(a)
            assert stepped == bool(a)

    @pytest.mark.slow
    def test_bank_converges_per_stream(self):
        """Every stream of a bank fed its own separation problem converges."""
        ecfg, ocfg = _cfgs(P=16, mu=3e-3)
        S = 4
        bank = SeparatorBank(ecfg, ocfg, n_streams=S)
        state = bank.init(jax.random.PRNGKey(0))
        pipe = MixedSignals(m=4, n=2, batch=16, seed=0, streams=S)
        step = jax.jit(lambda st, x: bank.step(st, x))
        for k in range(1500):
            state, _ = step(state, pipe.batch_for_step(k))
        pi = bank.performance_index(state, pipe.mixing_at(1499))
        assert pi.shape == (S,)
        assert float(jnp.max(pi)) < 0.2, np.asarray(pi)


class TestSlotHelpers:
    def test_stack_states_inverts_slot_state(self):
        """stack/slot round-trip: a bank rebuilt from its per-slot states is
        the same bank (the warm-migration path)."""
        ecfg, ocfg = _cfgs()
        bank = SeparatorBank(ecfg, ocfg, n_streams=5)
        state = bank.init(jax.random.PRNGKey(4))
        rebuilt = SeparatorBank.stack_states(
            [bank.slot_state(state, s) for s in range(5)]
        )
        for a, b in zip(state, rebuilt):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestBankSharding:
    def test_sharded_step_matches_local(self):
        ecfg, ocfg = _cfgs(P=8)
        bank = SeparatorBank(ecfg, ocfg, n_streams=4)
        key = jax.random.PRNGKey(1)
        state = bank.init(key)
        X = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, 4))
        mesh = jax.make_mesh((1,), ("stream",))
        sharded_step = make_sharded_bank_step(bank, mesh)
        st_sh, Y_sh = sharded_step(state, X)
        st_lo, Y_lo = bank.step(state, X)
        np.testing.assert_allclose(
            np.asarray(st_sh.B), np.asarray(st_lo.B), rtol=1e-6, atol=1e-7
        )
        np.testing.assert_allclose(
            np.asarray(Y_sh), np.asarray(Y_lo), rtol=1e-6, atol=1e-7
        )

    def test_indivisible_streams_rejected(self):
        import types

        ecfg, ocfg = _cfgs()
        bank = SeparatorBank(ecfg, ocfg, n_streams=3)
        # divisibility is checked before shard_map is built, so a stub mesh
        # with a 2-way stream axis exercises the rejection on 1 CPU device
        stub = types.SimpleNamespace(shape={"stream": 2})
        with pytest.raises(ValueError, match="not divisible"):
            make_sharded_bank_step(bank, stub)
        # and 4 % 1 == 0 on a real 1-device mesh builds fine
        mesh = jax.make_mesh((1,), ("stream",))
        assert callable(make_sharded_bank_step(
            SeparatorBank(ecfg, ocfg, n_streams=4), mesh
        ))

    def test_bank_sharding_placement(self):
        from jax.sharding import NamedSharding

        ecfg, ocfg = _cfgs()
        bank = SeparatorBank(ecfg, ocfg, n_streams=2)
        mesh = jax.make_mesh((1,), ("stream",))
        sh = bank_sharding(mesh)
        state = bank.init(jax.random.PRNGKey(0))
        placed = jax.device_put(state, sh)
        assert isinstance(placed.B.sharding, NamedSharding)


class TestBankCheckpoint:
    def test_bank_state_roundtrip(self, tmp_path):
        from repro.checkpoint.checkpointer import Checkpointer

        ecfg, ocfg = _cfgs()
        bank = SeparatorBank(ecfg, ocfg, n_streams=8)
        key = jax.random.PRNGKey(2)
        state = bank.init(key)
        state, _ = bank.epoch(
            state, jax.random.normal(jax.random.fold_in(key, 1), (8, 64, 4))
        )
        ckpt = Checkpointer(tmp_path)
        ckpt.save(11, state._asdict())
        restored, step = ckpt.restore(jax.tree.map(jnp.zeros_like, state._asdict()))
        assert step == 11
        restored = BankState(**restored)
        for a, b in zip(state, restored):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestStreamedMixedSignals:
    def test_stream_axis_shapes_and_determinism(self):
        pipe = MixedSignals(m=4, n=2, batch=8, seed=0, streams=3)
        a = pipe.batch_for_step(5)
        assert a.shape == (3, 8, 4)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(pipe.batch_for_step(5)))

    def test_streams_are_distinct_problems(self):
        pipe = MixedSignals(m=4, n=2, batch=8, seed=0, streams=3)
        X = np.asarray(pipe.batch_for_step(0))
        assert not np.allclose(X[0], X[1])
        A = np.asarray(pipe.mixing_at(0))
        assert A.shape == (3, 4, 2)
        assert not np.allclose(A[0], A[1])

    def test_per_stream_drift_staggered(self):
        pipe = MixedSignals(m=4, n=2, batch=8, seed=0, streams=2, drift_rate=1e-3)
        d0 = np.asarray(pipe.mixing_at(500, 0) - pipe.mixing_at(0, 0))
        d1 = np.asarray(pipe.mixing_at(500, 1) - pipe.mixing_at(0, 1))
        assert np.abs(d0).max() > 1e-3 and np.abs(d1).max() > 1e-3
        assert not np.allclose(d0, d1)

    def test_dp_slices_stream_axis(self):
        pipe = MixedSignals(m=4, n=2, batch=8, seed=0, streams=4)
        full = pipe.batch_for_step(2, 0, 1)
        parts = jnp.concatenate(
            [pipe.batch_for_step(2, r, 2) for r in range(2)], axis=0
        )
        np.testing.assert_array_equal(np.asarray(full), np.asarray(parts))

    def test_legacy_single_stream_unchanged(self):
        pipe = MixedSignals(m=4, n=2, batch=8, seed=0)
        assert pipe.batch_for_step(0).shape == (8, 4)
        assert pipe.mixing_at(0).shape == (4, 2)
