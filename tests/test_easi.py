"""EASI algorithm: relative-gradient structure, equivariance (the paper's
namesake property), whitening, and baseline SGD convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import easi as easi_lib
from repro.core import metrics
from repro.core.easi import EASIConfig
from repro.data import signals


def _cfg(n=2, m=4, mu=2e-3, nl="cubic", **kw):
    return EASIConfig(n_components=n, n_features=m, mu=mu, nonlinearity=nl, **kw)


class TestRelativeGradient:
    def test_symmetric_plus_skew_structure(self):
        """H = (I − yyᵀ) + (ygᵀ − gyᵀ): sym part is I−yyᵀ, skew part is HOS."""
        y = jnp.array([0.5, -1.2, 0.3])
        g = easi_lib.relative_gradient(y, lambda v: v**3)
        sym = 0.5 * (g + g.T)
        skew = 0.5 * (g - g.T)
        np.testing.assert_allclose(
            np.asarray(sym), np.asarray(jnp.eye(3) - jnp.outer(y, y)), atol=1e-6
        )
        gy = y**3
        np.testing.assert_allclose(
            np.asarray(skew), np.asarray(jnp.outer(y, gy) - jnp.outer(gy, y)), atol=1e-6
        )

    def test_zero_at_whitened_independent_fixed_point(self):
        """E[H] ≈ 0 for unit-variance independent symmetric sources — the
        stationary point of the separator."""
        key = jax.random.PRNGKey(0)
        Y = jax.random.uniform(key, (200_000, 2), minval=-1.7320508, maxval=1.7320508)
        w = jnp.ones((Y.shape[0],)) / Y.shape[0]
        S = easi_lib.batched_relative_gradient(Y, w, lambda v: v**3)
        assert float(jnp.max(jnp.abs(S))) < 2e-2

    @given(
        n=st.integers(2, 6),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=10, deadline=None)
    def test_batched_equals_sum_of_persample(self, n, seed):
        key = jax.random.PRNGKey(seed)
        P = 17
        Y = jax.random.normal(key, (P, n))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (P,)))
        batched = easi_lib.batched_relative_gradient(Y, w, jnp.tanh)
        manual = sum(
            w[p] * easi_lib.relative_gradient(Y[p], jnp.tanh) for p in range(P)
        )
        np.testing.assert_allclose(np.asarray(batched), np.asarray(manual), rtol=2e-4, atol=2e-4)

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_weight_linearity(self, seed):
        """S(w1 + w2) = S(w1) + S(w2) — the property that makes DP-EASI exact."""
        key = jax.random.PRNGKey(seed)
        Y = jax.random.normal(key, (32, 3))
        w1 = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (32,)))
        w2 = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (32,)))
        g = lambda v: v**3
        s12 = easi_lib.batched_relative_gradient(Y, w1 + w2, g)
        s1 = easi_lib.batched_relative_gradient(Y, w1, g)
        s2 = easi_lib.batched_relative_gradient(Y, w2, g)
        np.testing.assert_allclose(np.asarray(s12), np.asarray(s1 + s2), rtol=1e-4, atol=1e-4)


class TestEquivariance:
    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_global_system_independent_of_mixing(self, seed):
        """Equivariance: with square invertible A, the trajectory of C = B·A
        depends only on C0 and the sources — never on A itself."""
        n = 2
        key = jax.random.PRNGKey(seed)
        kS, kA1, kA2, kC = jax.random.split(key, 4)
        S = signals.source_bank(kS, n, 500)
        C0 = jnp.eye(n) + 0.3 * jax.random.normal(kC, (n, n))
        cfg = _cfg(n=n, m=n, mu=1e-3)

        traces = []
        for kA in (kA1, kA2):
            A = jax.random.normal(kA, (n, n)) + 2.0 * jnp.eye(n)  # well-conditioned
            B0 = C0 @ jnp.linalg.inv(A)
            X = S @ A.T
            B_fin, _ = easi_lib.easi_sgd_scan(B0, X, cfg)
            traces.append(B_fin @ A)
        np.testing.assert_allclose(
            np.asarray(traces[0]), np.asarray(traces[1]), rtol=5e-3, atol=5e-3
        )


class TestConvergence:
    def test_sgd_separates_paper_problem(self):
        """m=4 → n=2 (the paper's Table I problem): Amari index drops below
        threshold from a random init."""
        key = jax.random.PRNGKey(3)
        A, S, X = signals.make_problem(key, m=4, n=2, T=40_000)
        cfg = _cfg()
        B0 = easi_lib.init_separation_matrix(cfg, jax.random.PRNGKey(7))
        pi0 = metrics.amari_index(metrics.global_system(B0, A))
        B, _ = easi_lib.easi_sgd_scan(B0, X, cfg)
        pi = metrics.amari_index(metrics.global_system(B, A))
        assert float(pi) < 0.12, f"did not separate: {float(pi0):.3f} -> {float(pi):.3f}"
        assert float(pi) < float(pi0) / 3

    def test_whitening_emerges(self):
        key = jax.random.PRNGKey(4)
        A, S, X = signals.make_problem(key, m=4, n=2, T=40_000)
        cfg = _cfg()
        B0 = easi_lib.init_separation_matrix(cfg, jax.random.PRNGKey(8))
        B, Y = easi_lib.easi_sgd_scan(B0, X, cfg)
        err = metrics.whiteness_error(Y[-10_000:])
        assert float(err) < 0.15

    def test_normalized_variant_stable_at_large_mu(self):
        key = jax.random.PRNGKey(5)
        A, S, X = signals.make_problem(key, m=4, n=2, T=20_000)
        cfg = _cfg(mu=2e-2, normalized=True)
        B0 = easi_lib.init_separation_matrix(cfg, jax.random.PRNGKey(9))
        B, _ = easi_lib.easi_sgd_scan(B0, X, cfg)
        assert bool(jnp.all(jnp.isfinite(B)))


class TestConfigValidation:
    def test_too_many_components_rejected(self):
        with pytest.raises(ValueError):
            EASIConfig(n_components=5, n_features=4)

    def test_transform_shape(self):
        cfg = _cfg()
        B = jnp.ones((2, 4))
        X = jnp.ones((7, 4))
        assert easi_lib.transform(B, X).shape == (7, 2)
